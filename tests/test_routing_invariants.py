"""Routing-invariant property tests (hypothesis).

The invariants every routing producer must hold, whatever the scenario:

* `optimize_routing` / `refine_routing` only ever route a pair to one of
  its candidate ports (`candidate_matrix`), and respect the port-capacity
  headroom rule whenever a feasible placement exists;
* `refine_routing` cost is monotonically non-increasing move by move
  (every accepted move's saving is positive and they sum to the claimed
  total), and its 2-exchange (pair-swap) moves unlock improvements the
  single-pair move cannot express when both ports sit at their headroom.
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.pricing import flat_rate
from repro.fleet.plan import (
    PairSpec,
    PortSpec,
    TopologySpec,
    build_topology_scenario,
    optimize_routing,
    refine_routing,
)


def _mean_loads(topo, routing, demand) -> np.ndarray:
    d = np.minimum(
        np.asarray(demand, np.float64),
        np.array([p.capacity_gb_hr for p in topo.pairs])[:, None],
    ).mean(axis=1)
    loads = np.zeros(topo.n_ports)
    paths = (
        routing.paths
        if hasattr(routing, "paths")
        else [(int(m),) for m in routing]
    )
    for i, path in enumerate(paths):
        for m in path:
            loads[int(m)] += d[i]
    return loads


# ---------------------------------------------------------------------------
# optimize_routing invariants
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000))
@settings(max_examples=5, deadline=None)
def test_optimize_routing_candidates_and_headroom(seed):
    """Sampled facility graphs: the greedy packing must stay inside every
    pair's candidate set, and any port loaded past the headroom ceiling
    must be explainable as fallback (some routed pair had NO candidate with
    room at any packing order) — never a silent capacity violation."""
    rng = np.random.default_rng(seed)
    sc = build_topology_scenario(
        int(rng.integers(6, 20)),
        n_facilities=int(rng.integers(2, 5)),
        horizon=300,
        seed=seed,
        demand_scale=float(rng.uniform(0.3, 3.0)),
    )
    headroom = 0.8
    r = optimize_routing(sc.topo, sc.demand, headroom=headroom)
    cand = sc.topo.candidate_matrix()
    prim = r.primary
    for i, m in enumerate(prim):
        assert cand[i, int(m)], f"pair {i} routed to non-candidate port {m}"

    caps = np.array([p.capacity_gb_hr for p in sc.topo.ports])
    loads = _mean_loads(sc.topo, r, sc.demand)
    mean_d = np.minimum(
        np.asarray(sc.demand, np.float64),
        np.array([p.capacity_gb_hr for p in sc.topo.pairs])[:, None],
    ).mean(axis=1)
    for m in np.where(loads > headroom * caps + 1e-9)[0]:
        # Overloaded port: every pair on it must have been a fallback —
        # i.e. even ALONE it cannot fit any of its candidates' remaining
        # headroom given the total candidate demand pressure. The weakest
        # sound check: one of its pairs alone exceeds the headroom of all
        # its candidates, OR total demand over the candidate set exceeds
        # the candidate capacity — both mean no feasible packing existed.
        for i in np.where(prim == m)[0]:
            cands = sc.topo.pairs[i].candidates
            alone_infeasible = all(
                mean_d[i] > headroom * caps[c] for c in cands
            )
            pressure = sum(mean_d[j] for j in range(sc.n_pairs)
                           if set(sc.topo.pairs[j].candidates) & set(cands))
            cap_total = sum(headroom * caps[c] for c in cands)
            # A genuine fallback implies every candidate was full at
            # placement time, which (summing the k rejection inequalities)
            # implies pressure > cap_total − k·mean_d[i]; anything below
            # that bound means a feasible port was ignored.
            slack = len(cands) * mean_d[i]
            assert alone_infeasible or pressure > cap_total - slack, (
                f"port {m} over headroom but pair {i} had a feasible "
                "candidate — the packer violated its own capacity rule"
            )


def test_optimize_routing_headroom_respected_when_feasible():
    """Ample capacity: NO port may exceed the headroom ceiling."""
    sc = build_topology_scenario(12, n_facilities=3, horizon=300, seed=3,
                                 demand_scale=0.2)
    r = optimize_routing(sc.topo, sc.demand, headroom=0.8)
    caps = np.array([p.capacity_gb_hr for p in sc.topo.ports])
    loads = _mean_loads(sc.topo, r, sc.demand)
    finite = np.isfinite(caps)
    assert np.all(loads[finite] <= 0.8 * caps[finite] + 1e-9)


# ---------------------------------------------------------------------------
# refine_routing invariants
# ---------------------------------------------------------------------------


def _replay_capacity_rule(topo, routing, demand, moves, headroom=0.8):
    """Re-apply the accepted moves and assert the packer's capacity rule
    held at EVERY accepted move (not just in the final state)."""
    r = np.asarray(routing, np.int64).copy()
    mean_d = np.minimum(
        np.asarray(demand, np.float64),
        np.array([p.capacity_gb_hr for p in topo.pairs])[:, None],
    ).mean(axis=1)
    caps = np.array([p.capacity_gb_hr for p in topo.ports])
    loads = _mean_loads(topo, r, demand)

    def fits(m, load):
        return not math.isfinite(caps[m]) or load <= headroom * caps[m] + 1e-9

    for mv in moves:
        if isinstance(mv[0], tuple):  # swap: ((p, q), (m1, m2), (m2, m1), s)
            (p, q), (m1, m2) = mv[0], mv[1]
            assert fits(m1, loads[m1] - mean_d[p] + mean_d[q])
            assert fits(m2, loads[m2] - mean_d[q] + mean_d[p])
            loads[m1] += mean_d[q] - mean_d[p]
            loads[m2] += mean_d[p] - mean_d[q]
            r[p], r[q] = m2, m1
        else:                          # single: (p, m1, m2, s)
            p, m1, m2 = mv[0], mv[1], mv[2]
            assert fits(m2, loads[m2] + mean_d[p])
            loads[m1] -= mean_d[p]
            loads[m2] += mean_d[p]
            r[p] = m2
    return r


@given(seed=st.integers(0, 10_000))
@settings(max_examples=4, deadline=None)
def test_refine_routing_invariants(seed):
    """Sampled scenarios, deliberately-degraded starting routing: refined
    routing stays inside candidate sets, every accepted move saves cost
    (monotone non-increasing), the savings sum to the claimed drop, the
    move replay respects capacity headroom, and move_mix counts the moves."""
    rng = np.random.default_rng(seed)
    sc = build_topology_scenario(
        10, n_facilities=3, horizon=400, seed=seed,
        demand_scale=float(rng.uniform(0.5, 2.0)),
    )
    # Degrade the greedy routing: park some pairs on their worst candidate.
    r0 = optimize_routing(sc.topo, sc.demand)
    for i, pr in enumerate(sc.topo.pairs):
        if len(pr.candidates) > 1 and rng.random() < 0.5:
            r0 = r0.replace_path(
                i,
                int(rng.choice(
                    [c for c in pr.candidates if c != r0.primary[i]]
                )),
            )
    refined, info = refine_routing(sc.topo, sc.demand, r0, max_moves=6)

    sc.topo.validate_routing(refined)  # candidate invariant
    assert info["cost_after"] <= info["cost_before"] + 1e-6
    savings = [m[3] for m in info["moves"]]
    assert all(s > 0 for s in savings)  # monotone: every accepted move saves
    assert info["cost_before"] - info["cost_after"] == pytest.approx(
        sum(savings), rel=1e-9, abs=1e-6
    )
    assert sum(info["move_mix"].values()) == len(info["moves"])
    assert info["move_mix"]["relay"] == 0  # pure 1-hop candidate sets
    got = _replay_capacity_rule(
        sc.topo, r0.primary, sc.demand, info["moves"]
    )
    np.testing.assert_array_equal(got, refined.primary)


def test_pair_swap_unlocks_headroom_locked_exchange():
    """Both ports at capacity headroom: no SINGLE move is feasible, but the
    2-exchange that swaps the hot pair onto the cheap port is — and the
    local search must find it (the satellite's new move type)."""
    mk = lambda n, c: PortSpec(
        name=n, facility=f"f-{n}", cloud="aws", L_cci=2.0, V_cci=0.1,
        c_cci=c, capacity_gb_hr=130.0, D=6, T_cci=12, h=12,
    )
    mk_pair = lambda n: PairSpec(
        n, "gcp", "aws", 0.105, flat_rate(0.1), candidates=(0, 1)
    )
    topo = TopologySpec(
        ports=(mk("cheap", 0.01), mk("dear", 0.2)),
        pairs=(mk_pair("hot"), mk_pair("cold")),
    )
    d = np.stack([np.full(600, 100.0), np.full(600, 80.0)])
    bad = topo.plan([1, 0])  # hot pair on the dear port, cold on the cheap
    # Single moves are capacity-blocked (100+80 > 0.8*130 on either port)...
    refined_ns, info_ns = refine_routing(
        topo, d, bad, max_moves=4, swap_moves=False
    )
    np.testing.assert_array_equal(refined_ns.primary, [1, 0])
    assert info_ns["moves"] == [] and info_ns["move_mix"]["swap"] == 0
    # ...but the swap is feasible (each port keeps one pair) and pays.
    refined, info = refine_routing(topo, d, bad, max_moves=4)
    np.testing.assert_array_equal(refined.primary, [0, 1])
    assert info["move_mix"] == {"single": 0, "swap": 1, "relay": 0}
    ((p, q), (m1, m2), (m2b, m1b), saving) = info["moves"][0]
    assert {p, q} == {0, 1} and {m1, m2} == {0, 1} and saving > 0
    assert info["cost_after"] < info["cost_before"]
