"""Tests for the ToggleCCI FSM (paper §VI, Fig. 5)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
import hypothesis.extra.numpy as hnp

import jax
import jax.numpy as jnp

from repro.core.costmodel import hourly_cost_series
from repro.core.pricing import CostParams, flat_rate, make_scenario
from repro.core.togglecci import OFF, ON, WAITING, run_togglecci, run_togglecci_scan
from repro.traffic.traces import bursty_trace, constant_trace

P = make_scenario("gcp", "aws")


def small_params(**kw):
    kw.setdefault("D", 5)
    kw.setdefault("T_cci", 12)
    kw.setdefault("h", 12)
    return CostParams(1.0, 0.1, 0.02, 0.1, flat_rate(0.1), **kw)


def demand_strategy(max_t=500):
    return hnp.arrays(np.float64, st.integers(10, max_t), elements=st.floats(0, 5e3))


# ---------------------------------------------------------------------------
# FSM invariants
# ---------------------------------------------------------------------------


@given(demand_strategy())
def test_fsm_invariants(d):
    params = small_params()
    res = run_togglecci(params, d)
    st_tr, x = res.state, res.x
    # x == 1 exactly in ON.
    np.testing.assert_array_equal(x == 1, st_tr == ON)
    # WAITING runs are exactly D hours followed by ON.
    t = 0
    T = len(st_tr)
    while t < T:
        if st_tr[t] == WAITING:
            run = 0
            while t < T and st_tr[t] == WAITING:
                run += 1
                t += 1
            if t < T:  # not truncated by horizon
                assert run == params.D
                assert st_tr[t] == ON
        else:
            t += 1
    # ON runs last at least T_cci hours (unless truncated by the horizon).
    t = 0
    while t < T:
        if st_tr[t] == ON:
            run = 0
            while t < T and st_tr[t] == ON:
                run += 1
                t += 1
            if t < T:
                assert run >= params.T_cci
        else:
            t += 1


@given(demand_strategy())
def test_initial_state_off(d):
    res = run_togglecci(small_params(), d)
    assert res.state[0] in (OFF, WAITING)  # hour 0 can request, never serve CCI
    assert res.x[0] == 0


def test_zero_demand_stays_off():
    d = np.zeros(1000)
    res = run_togglecci(small_params(), d)
    assert (res.state == OFF).all()
    assert res.total_cost == pytest.approx(1000 * small_params().L_vpn)


def test_sustained_high_demand_activates():
    params = small_params()
    d = np.full(500, 1e4)  # VPN at 0.1 $/GB vs CCI at 0.02 -> CCI wins big
    res = run_togglecci(params, d)
    assert len(res.requests) == 1
    first_on = np.argmax(res.state == ON)
    assert res.state[first_on - 1] == WAITING
    assert (res.state[first_on:] == ON).all(), "high demand: stays ON forever"


def test_hysteresis_prevents_oscillation():
    """Demand hovering at breakeven: two thresholds (0.9/1.1) must produce
    far fewer mode switches than a single threshold (1.0/1.0)."""
    from repro.core.pricing import breakeven_rate_gb_per_hour

    rate = breakeven_rate_gb_per_hour(P)
    rng = np.random.default_rng(7)
    d = rate * rng.normal(1.0, 0.15, size=5000).clip(0, None)
    hyst = run_togglecci(P, d)
    import dataclasses

    single = dataclasses.replace(P, theta1=1.0, theta2=1.0)
    nohyst = run_togglecci(single, d)
    switches = lambda r: len(r.requests) + len(r.releases)
    assert switches(hyst) <= switches(nohyst)


def test_renew_in_chunks_releases_only_at_multiples():
    params = small_params()
    rng = np.random.default_rng(3)
    d = np.where(rng.random(800) < 0.5, 1e4, 0.0)
    res = run_togglecci(params, d, renew_in_chunks=True)
    # Every ON run must be an exact multiple of T_cci (unless horizon-cut).
    t, T = 0, len(res.state)
    while t < T:
        if res.state[t] == ON:
            run = 0
            while t < T and res.state[t] == ON:
                run += 1
                t += 1
            if t < T:
                assert run % params.T_cci == 0
        else:
            t += 1


# ---------------------------------------------------------------------------
# scan implementation equivalence
# ---------------------------------------------------------------------------


@given(demand_strategy(max_t=400))
@settings(max_examples=15)
def test_scan_matches_python(d):
    params = small_params()
    costs = hourly_cost_series(params, d)
    ref = run_togglecci(params, d, costs=costs)
    out = run_togglecci_scan(
        params, jnp.asarray(costs.vpn, jnp.float32), jnp.asarray(costs.cci, jnp.float32)
    )
    np.testing.assert_array_equal(np.asarray(out["x"]), ref.x)
    np.testing.assert_array_equal(np.asarray(out["state"]), ref.state)
    assert float(out["total_cost"]) == pytest.approx(ref.total_cost, rel=1e-4)


def test_scan_matches_python_paper_params_bursty():
    d = bursty_trace(seed=11).sum(axis=1)
    costs = hourly_cost_series(P, d)
    ref = run_togglecci(P, d, costs=costs)
    out = run_togglecci_scan(P, jnp.asarray(costs.vpn), jnp.asarray(costs.cci))
    np.testing.assert_array_equal(np.asarray(out["x"]), ref.x)


def test_scan_vmaps_over_scenarios():
    ds = np.stack([bursty_trace(seed=s).sum(axis=1) for s in range(4)])
    vpn = np.stack([hourly_cost_series(P, d).vpn for d in ds])
    cci = np.stack([hourly_cost_series(P, d).cci for d in ds])
    fn = jax.vmap(lambda v, c: run_togglecci_scan(P, v, c)["total_cost"])
    totals = np.asarray(fn(jnp.asarray(vpn), jnp.asarray(cci)))
    refs = np.array([run_togglecci(P, d).total_cost for d in ds])
    np.testing.assert_allclose(totals, refs, rtol=1e-4)


# ---------------------------------------------------------------------------
# Window-sum precision (float64 regression) + traceable ToggleParams
# ---------------------------------------------------------------------------


def _straddling_costs(T=4096, h=24):
    """Costs whose window comparison sits a hair on the no-request side of
    θ₁: r_cci = θ₁·r_vpn + h·ε with ε = 1e-3. A float32 prefix-sum window
    (cumsums reach ~4e6, ulp ~0.5) cannot resolve h·ε = 0.024 and flips the
    OFF->WAITING decision; float64 must not."""
    params = small_params(h=h)
    vpn = np.full(T, 1024.0)
    cci = params.theta1 * vpn + 1e-3
    return params, vpn, cci


def test_scan_float64_window_survives_threshold_straddle():
    params, vpn, cci = _straddling_costs()
    from repro.core.costmodel import HourlyCosts

    zeros = np.zeros_like(vpn)
    costs = HourlyCosts(vpn_lease=vpn, vpn_transfer=zeros,
                        cci_lease=cci, cci_transfer=zeros)
    ref = run_togglecci(params, np.zeros_like(vpn), costs=costs)
    assert ref.requests == [], "exact math: never requests"
    # float32 inputs, concrete path: window sums must accumulate in float64.
    out = run_togglecci_scan(
        params, jnp.asarray(vpn, jnp.float32), jnp.asarray(cci, jnp.float32)
    )
    np.testing.assert_array_equal(np.asarray(out["x"]), ref.x)
    assert (np.asarray(out["state"]) == OFF).all()
    # Demonstrate the straddle is real: a float32 prefix-difference window
    # DOES misorder the comparison somewhere in the horizon.
    pref32 = np.cumsum(vpn.astype(np.float32), dtype=np.float32)
    cpref32 = np.cumsum(cci.astype(np.float32), dtype=np.float32)
    t = np.arange(params.h, len(vpn))
    r_vpn32 = pref32[t - 1] - pref32[t - params.h - 1]
    r_cci32 = cpref32[t - 1] - cpref32[t - params.h - 1]
    assert (r_cci32 < params.theta1 * r_vpn32).any(), (
        "float32 windows should flip somewhere (else this regression test "
        "lost its teeth)"
    )


def test_scan_accepts_traceable_toggle_params():
    """ToggleParams fields are array operands: one compiled scan serves
    different (θ, h, D, T_cci) without retracing, and vmaps over them."""
    from repro.core.togglecci import ToggleParams

    d = bursty_trace(horizon=1200, seed=5).sum(axis=1)
    costs = hourly_cost_series(small_params(), d)
    vpn = jnp.asarray(costs.vpn)
    cci = jnp.asarray(costs.cci)

    jit_scan = jax.jit(
        lambda tp, v, c: run_togglecci_scan(tp, v, c)["x"]
    )
    variants = [small_params(), small_params(D=9, T_cci=30, h=48)]
    for p in variants:
        tp = ToggleParams.from_cost_params(p)
        np.testing.assert_array_equal(
            np.asarray(jit_scan(tp, vpn, cci)), run_togglecci(p, d, costs=costs).x
        )

    # vmap over stacked heterogeneous params against broadcast costs.
    tps = ToggleParams(
        theta1=jnp.asarray([p.theta1 for p in variants], jnp.float32),
        theta2=jnp.asarray([p.theta2 for p in variants], jnp.float32),
        h=jnp.asarray([p.h for p in variants], jnp.int32),
        D=jnp.asarray([p.D for p in variants], jnp.int32),
        T_cci=jnp.asarray([p.T_cci for p in variants], jnp.int32),
    )
    xs = jax.vmap(lambda tp: run_togglecci_scan(tp, vpn, cci)["x"])(tps)
    for i, p in enumerate(variants):
        np.testing.assert_array_equal(
            np.asarray(xs[i]), run_togglecci(p, d, costs=costs).x
        )
