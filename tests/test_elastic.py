"""Elastic restart: a checkpoint written under one mesh restores onto a
DIFFERENT mesh shape (resharding-on-restore) with identical values — the
fault-tolerance path a fleet uses when it loses a slice and restarts smaller.

Runs in a subprocess with 8 forced host devices (conftest keeps the main
process single-device)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_checkpoint_restores_across_mesh_shapes(tmp_path):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    script = textwrap.dedent(f"""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_config, reduce_config
        from repro.dist.sharding import param_shardings
        from repro.launch.mesh import make_host_mesh
        from repro.models import lm

        cfg = reduce_config(get_config("tinyllama-1.1b"))
        params = lm.init_params(cfg, jax.random.PRNGKey(0))

        # Save under a 2x4 mesh (8 devices).
        mesh_a = make_host_mesh(data=2, model=4)
        sh_a = param_shardings(mesh_a, jax.eval_shape(lambda: params))
        placed = jax.tree.map(jax.device_put, params, sh_a)
        mgr = CheckpointManager({str(tmp_path)!r}, keep=1)
        mgr.save(3, placed)

        # Restore under a DIFFERENT 4x2 mesh (simulating an elastic restart).
        mesh_b = make_host_mesh(data=4, model=2)
        sh_b = param_shardings(mesh_b, jax.eval_shape(lambda: params))
        restored = mgr.restore(jax.eval_shape(lambda: params), shardings=sh_b)

        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # The restored leaves really live under the new mesh's shardings.
        leaf = jax.tree.leaves(restored)[0]
        assert leaf.sharding.mesh.shape == dict(mesh_b.shape), leaf.sharding
        print("OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout
