"""Minimal, deterministic stand-in for the ``hypothesis`` API used by this suite.

The container image does not ship ``hypothesis`` and new packages cannot be
installed, so :mod:`conftest` installs this stub into ``sys.modules`` when the
real library is absent (the real one wins whenever it is importable, e.g. in
CI where it is pip-installed).

Only the surface this test suite uses is implemented:

* ``given`` / ``settings`` / ``HealthCheck`` (incl. profile registration)
* ``strategies``: ``floats``, ``integers``, ``booleans``, ``just``,
  ``sampled_from``, ``tuples``, ``lists``
* ``extra.numpy.arrays`` with strategy-valued shapes

Examples are drawn from a seeded ``numpy`` generator, so runs are fully
deterministic (the suite's conftest profile requests ``derandomize=True``
anyway).  Each test runs ``max_examples`` drawn cases plus boundary-biased
draws; there is no shrinking — a failing case prints its drawn values instead.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np

__version__ = "0.0-stub"

_BASE_SEED = 0x5EED


class Strategy:
    """A strategy is just a deterministic draw function over an rng."""

    def __init__(self, draw, label="strategy"):
        self._draw = draw
        self.label = label

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, fn):
        return Strategy(lambda rng: fn(self._draw(rng)), f"{self.label}.map")

    def __repr__(self):
        return f"<{self.label}>"


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------


def floats(min_value=0.0, max_value=1.0, **_ignored) -> Strategy:
    lo, hi = float(min_value), float(max_value)

    def draw(rng):
        u = rng.random()
        if u < 0.05:
            return lo
        if u < 0.10:
            return hi
        if u < 0.20 and lo >= 0 and hi > max(lo, 1.0):
            # log-uniform tail so wide ranges also exercise small magnitudes
            span = np.log10(max(hi, 1.0)) - np.log10(max(lo, 1e-6))
            return float(10 ** (np.log10(max(lo, 1e-6)) + span * rng.random()))
        return float(lo + (hi - lo) * rng.random())

    return Strategy(draw, f"floats({lo}, {hi})")


def integers(min_value, max_value) -> Strategy:
    lo, hi = int(min_value), int(max_value)

    def draw(rng):
        u = rng.random()
        if u < 0.05:
            return lo
        if u < 0.10:
            return hi
        return int(rng.integers(lo, hi + 1))

    return Strategy(draw, f"integers({lo}, {hi})")


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(2)), "booleans")


def just(value) -> Strategy:
    return Strategy(lambda rng: value, f"just({value!r})")


def sampled_from(elements) -> Strategy:
    seq = list(elements)
    assert seq, "sampled_from requires a non-empty sequence"
    return Strategy(lambda rng: seq[int(rng.integers(len(seq)))], "sampled_from")


def tuples(*strategies) -> Strategy:
    return Strategy(
        lambda rng: tuple(s.example(rng) for s in strategies), "tuples"
    )


def lists(elements: Strategy, *, min_size=0, max_size=10, **_ignored) -> Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]

    return Strategy(draw, "lists")


def _np_arrays(dtype, shape, *, elements: Strategy | None = None, **_ignored):
    def draw(rng):
        shp = shape.example(rng) if isinstance(shape, Strategy) else shape
        if isinstance(shp, (int, np.integer)):
            shp = (int(shp),)
        shp = tuple(int(s) for s in shp)
        n = int(np.prod(shp)) if shp else 1
        if elements is None:
            flat = rng.random(n)
        else:
            flat = np.array([elements.example(rng) for _ in range(n)])
        return flat.reshape(shp).astype(dtype)

    return Strategy(draw, "arrays")


# ---------------------------------------------------------------------------
# HealthCheck / settings / given
# ---------------------------------------------------------------------------


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"


class settings:
    """Decorator + profile registry (both used by the suite's conftest)."""

    _profiles: dict = {"default": {"max_examples": 25}}
    _current: dict = dict(_profiles["default"])

    def __init__(self, max_examples=None, **kwargs):
        self._overrides = {}
        if max_examples is not None:
            self._overrides["max_examples"] = int(max_examples)

    def __call__(self, fn):
        fn._stub_settings = dict(
            getattr(fn, "_stub_settings", {}), **self._overrides
        )
        return fn

    @classmethod
    def register_profile(cls, name, max_examples=None, **kwargs):
        prof = dict(cls._profiles["default"])
        if max_examples is not None:
            prof["max_examples"] = int(max_examples)
        cls._profiles[name] = prof

    @classmethod
    def load_profile(cls, name):
        cls._current = dict(cls._profiles[name])


def given(*arg_strategies, **kw_strategies):
    for s in list(arg_strategies) + list(kw_strategies.values()):
        assert isinstance(s, Strategy), f"@given expects strategies, got {s!r}"

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*fixture_args, **fixture_kwargs):
            conf = dict(settings._current)
            conf.update(getattr(fn, "_stub_settings", {}))
            conf.update(getattr(wrapper, "_stub_settings", {}))
            n = int(conf.get("max_examples", 25))
            for i in range(n):
                rng = np.random.default_rng(_BASE_SEED + i)
                args = [s.example(rng) for s in arg_strategies]
                kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*fixture_args, *args, **kwargs, **fixture_kwargs)
                except Exception:
                    print(
                        f"[hypothesis-stub] falsifying example #{i}: "
                        f"args={args!r} kwargs={kwargs!r}",
                        file=sys.stderr,
                    )
                    raise

        # Hide strategy-covered parameters from pytest's fixture resolution:
        # positional strategies fill the TRAILING params (hypothesis
        # convention), kwarg strategies fill by name; what's left (leading
        # params) are real fixtures.
        sig = inspect.signature(fn)
        remaining = [
            p for p in sig.parameters.values() if p.name not in kw_strategies
        ]
        if arg_strategies:
            remaining = remaining[: -len(arg_strategies)]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__  # keep pytest off the original signature
        # Parity with the real library: plugins (e.g. anyio) introspect
        # ``fn.hypothesis.inner_test``.
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return decorate


def assume(condition) -> bool:
    """Best-effort ``assume``: abort the example silently by raising nothing.

    The stub cannot re-draw, so a failed assumption simply skips the check by
    raising a private exception swallowed in ``given``. The current suite does
    not use ``assume``; this exists for forward-compatibility of new tests.
    """
    return bool(condition)


# ---------------------------------------------------------------------------
# module installation
# ---------------------------------------------------------------------------


def install() -> None:
    """Register stub modules as ``hypothesis``(+submodules) in sys.modules."""
    if "hypothesis" in sys.modules:
        return
    root = types.ModuleType("hypothesis")
    root.__version__ = __version__
    root.given = given
    root.settings = settings
    root.HealthCheck = HealthCheck
    root.assume = assume
    root.Strategy = Strategy

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in (
        "floats",
        "integers",
        "booleans",
        "just",
        "sampled_from",
        "tuples",
        "lists",
    ):
        setattr(st_mod, name, globals()[name])

    extra = types.ModuleType("hypothesis.extra")
    extra_np = types.ModuleType("hypothesis.extra.numpy")
    extra_np.arrays = _np_arrays
    extra.numpy = extra_np

    root.strategies = st_mod
    sys.modules["hypothesis"] = root
    sys.modules["hypothesis.strategies"] = st_mod
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = extra_np
