"""Tests for the traffic substrate: generators + the §IV link simulator."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.traffic import linksim as L
from repro.traffic.mirage import mirage_trace
from repro.traffic.puffer import puffer_trace
from repro.traffic.traces import bursty_trace, constant_trace

# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------


def test_constant_trace():
    d = constant_trace(100.0, horizon=500, n_pairs=4)
    assert d.shape == (500, 4)
    np.testing.assert_allclose(d.sum(axis=1), 100.0)


@given(seed=st.integers(0, 100))
@settings(max_examples=10)
def test_bursty_trace_properties(seed):
    d = bursty_trace(horizon=4000, seed=seed)
    assert d.shape == (4000, 1)
    assert (d >= 0).all()
    # Roughly one burst/month of ~1 week at 400 GB/h -> mean in [10, 300].
    assert 0.0 <= d.mean() < 400.0


def test_bursty_trace_deterministic():
    np.testing.assert_array_equal(bursty_trace(seed=5), bursty_trace(seed=5))


def test_mirage_trace_shape_and_scale():
    d = mirage_trace(2000, horizon_days=14, n_pairs=3, seed=0)
    assert d.shape == (14 * 24, 3)
    assert (d >= 0).all()
    per_user_day = d.sum() / 14 / 2000
    assert 0.05 < per_user_day < 3.0, f"mobile-scale GB/user/day, got {per_user_day}"


def test_mirage_diurnal_pattern():
    d = mirage_trace(5000, horizon_days=30, seed=1).sum(axis=1)
    by_hour = d.reshape(30, 24).mean(axis=0)
    assert by_hour[19] > 3 * by_hour[3], "evening >> pre-dawn"


def test_mirage_scales_with_users():
    d1 = mirage_trace(1000, horizon_days=7, seed=2).sum()
    d2 = mirage_trace(10000, horizon_days=7, seed=2).sum()
    assert 7 < d2 / d1 < 13


def test_puffer_stable_and_cyclic():
    d = puffer_trace(horizon_days=28, seed=0)
    assert d.shape == (28 * 24, 7)
    agg = d.sum(axis=1)
    by_hour = agg.reshape(28, 24).mean(axis=0)
    assert by_hour.argmax() in (18, 19, 20, 21), "evening peak"
    # Stability: puffer's day-to-day variation is mild vs mirage burstiness.
    daily = agg.reshape(28, 24).sum(axis=1)
    assert daily.std() / daily.mean() < 0.3


# ---------------------------------------------------------------------------
# Link simulator — each §IV finding (F1-F8 in linksim docstring)
# ---------------------------------------------------------------------------


def test_f1_cci_hard_cap():
    """CCI never exceeds nominal; saturation = nominal - ~5% overhead."""
    for seed in range(5):
        r = L.measure_throughput("cci", "intra_region", utilization=1.0, repeats=5, seed=seed)
        assert r["max_gbps"] <= L.CCI_NOMINAL_GBPS
        assert 9.0 <= r["mean_gbps"] <= 9.6


def test_f2_nic_elastic_short_bursts():
    """Short bursts on a small NIC reach ~2x nominal (the paper's 4.16 on 2)."""
    path = L.PathConfig("cci", nic_nominal_gbps=2.0)
    flow = L.Flow(n_connections=10, per_conn_target_gbps=0.5, duration_s=60)
    m, series = L.simulate(path, [flow], seed=0, return_timeseries=True)
    assert series[:30].mean() > 1.3 * 2.0, "burst exceeds nominal NIC"
    # After warm-up the NIC converges back to nominal.
    path_long = L.PathConfig("cci", nic_nominal_gbps=2.0)
    flow_long = L.Flow(10, 0.5, 600)
    _, s2 = L.simulate(path_long, [flow_long], seed=0, return_timeseries=True)
    assert s2[320:].mean() <= 2.0 * 1.05


def test_f3_vlan_elastic_upward_only():
    path = L.PathConfig("cci", vlan_nominal_gbps=(5.0,))
    flow = L.Flow(10, 0.9, 600)
    _, s = L.simulate(path, [flow], seed=1, return_timeseries=True)
    assert s[:60].mean() > 5.0, "VLAN burst above nominal"
    assert s[320:].mean() >= 5.0 * 0.93, "never below nominal after warmup"


def test_f4_overbooked_vlan_fair_share():
    """Two 10G VLANs on a 10G CCI -> ~5 Gbps each (paper §IV-A)."""
    path = L.PathConfig("cci", vlan_nominal_gbps=(10.0, 10.0))
    flows = [L.Flow(10, 1.0, 400, 0), L.Flow(10, 1.0, 400, 1)]
    m = L.simulate(path, flows, seed=2)
    assert abs(m[0] - m[1]) < 0.5
    assert m.sum() <= L.CCI_NOMINAL_GBPS
    assert 4.2 <= m[0] <= 5.3


def test_f4_fair_share_within_capacity_no_throttle():
    """Overbooked VLAN but total under CCI cap: connections get fair shares."""
    path = L.PathConfig("cci", vlan_nominal_gbps=(5.0,))
    flows = [L.Flow(5, 0.4, 400, 0), L.Flow(5, 0.4, 400, 0)]
    m = L.simulate(path, flows, seed=3)
    assert abs(m[0] - m[1]) < 0.3


def test_f5_vpn_autoscale_dynamics():
    short = L.measure_throughput("vpn", utilization=1.0, duration_s=240, repeats=10)
    long_ = L.measure_throughput("vpn", utilization=1.0, duration_s=1200, repeats=10)
    assert short["mean_gbps"] < 0.9, "pre-autoscale: low"
    assert long_["mean_gbps"] > 1.0, "post-autoscale approaches 1.25"
    assert long_["max_gbps"] < 1.25 * 1.7


def test_f5_short_flows_exceed_cap():
    path = L.PathConfig("vpn")
    flow = L.Flow(10, 0.2, 25)  # 2 Gbps offered for 25 s
    m = L.simulate(path, [flow], seed=4)
    assert m[0] > L.VPN_TUNNEL_CAP_GBPS, "throttling hasn't kicked in yet"


def test_f6_internet_egress_cap():
    r = L.measure_throughput("internet_prem", utilization=1.0, n_connections=20, repeats=5)
    assert r["mean_gbps"] <= L.INTERNET_EGRESS_CAP_GBPS * 1.05
    # The same NIC fills a 10G CCI -> the cap is internet-specific.
    c = L.measure_throughput("cci", utilization=1.0, n_connections=20, repeats=5)
    assert c["mean_gbps"] > r["mean_gbps"]


def test_f7_bdp_intercontinental_drop():
    near = L.measure_throughput("cci", "intra_region", utilization=1.0, repeats=5)
    far = L.measure_throughput("cci", "inter_continent", utilization=1.0, repeats=5)
    assert far["mean_gbps"] < 0.5 * near["mean_gbps"]
    # Quantitative BDP check: 10 conns * window/RTT.
    expect = L._bdp_cap_gbps(L.RTT_MS["inter_continent"], 10)
    assert far["mean_gbps"] == pytest.approx(expect, rel=0.25)


def test_f8_standard_tier_can_beat_premium_intra_continent():
    wins = 0
    for seed in range(30):
        s = L.measure_throughput("internet_std", "intra_continent", utilization=0.7,
                                 repeats=1, seed=seed)
        p = L.measure_throughput("internet_prem", "intra_continent", utilization=0.7,
                                 repeats=1, seed=seed + 999)
        wins += s["mean_gbps"] > p["mean_gbps"]
    assert 1 <= wins <= 29, "standard occasionally (not always) beats premium"


def test_max_min_fair_properties():
    a = L.max_min_fair([1.0, 2.0, 10.0], 6.0)
    np.testing.assert_allclose(a, [1.0, 2.0, 3.0])
    a = L.max_min_fair([5.0, 5.0], 6.0)
    np.testing.assert_allclose(a, [3.0, 3.0])
    a = L.max_min_fair([1.0, 1.0], 100.0)
    np.testing.assert_allclose(a, [1.0, 1.0])  # never exceeds demand


def test_max_min_fair_zero_demand():
    np.testing.assert_array_equal(L.max_min_fair([0.0, 0.0, 0.0], 5.0), 0.0)
    np.testing.assert_array_equal(L.max_min_fair([], 5.0), np.zeros(0))
    a = L.max_min_fair([0.0, 4.0], 2.0)
    np.testing.assert_allclose(a, [0.0, 2.0])  # idle flows get nothing


def test_max_min_fair_single_flow():
    np.testing.assert_allclose(L.max_min_fair([3.0], 10.0), [3.0])
    np.testing.assert_allclose(L.max_min_fair([30.0], 10.0), [10.0])
    np.testing.assert_allclose(L.max_min_fair([3.0], 0.0), [0.0])


def test_max_min_fair_over_capacity_equal_tiny_demands_terminates():
    """Regression: capacity >> total demand with equal tiny demands used to
    spin forever (np.isclose against the original demands never fired)."""
    tiny = np.full(8, 1e-13)
    a = L.max_min_fair(tiny, 1.0)
    np.testing.assert_allclose(a, tiny)
    # And mixed magnitudes stay exact under over-capacity.
    d = np.array([1e-13, 5.0, 1e-13, 2.5])
    np.testing.assert_allclose(L.max_min_fair(d, 100.0), d)
