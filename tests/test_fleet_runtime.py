"""Streaming fleet runtime tests (the tentpole's bit-exactness contract).

The load-bearing property: N incremental ``FleetRuntime.step`` calls
reproduce one offline ``policy_scan`` DECISION-BIT-EXACTLY for all three
toggle policies. The airtight form pins the per-hour mode-cost series to the
runtime's own emitted columns (the same pinning contract
``plan_topology_reference`` documents): the runtime's carried prefix-ring
window state must then replicate ``policy_scan``'s float64 ``np.cumsum``
windows and FSM transitions exactly, over random windows/delays/thresholds
and regime-switching demand. Sampled-scenario tests additionally check the
streaming pricing stage against the jitted ``plan_fleet``/``plan_topology``
engines end-to-end (both policies' decisions and the cost series), plus the
live-SSM forecast mode, the endogenous-demand planner, and the collective
actuation path (int8 vs hierarchical selected by link modes).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.pricing import CostParams, TieredRate
from repro.fleet.plan import (
    build_fleet_scenario,
    build_topology_scenario,
    forecast_fleet_policy,
    forecast_gated_policy,
    forecast_topology_policy,
    hysteresis_policy,
    make_policy,
    optimize_routing,
    plan_fleet,
    plan_topology,
    policy_scan,
    reactive_policy,
)
from repro.fleet.stream import (
    ElasticFleetPlanner,
    FleetRuntime,
    streaming_forecast_policy,
)
from repro.fleet.policy import fit_cost_coef
from repro.fleet.spec import fleet_from_params

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _random_params(rng: np.random.Generator) -> CostParams:
    k = int(rng.integers(1, 4))
    bounds = np.sort(rng.uniform(50, 5000, size=k))
    rates = np.sort(rng.uniform(0.02, 0.2, size=k))[::-1]
    tier = TieredRate(tuple(bounds[:-1]) + (np.inf,), tuple(rates))
    return CostParams(
        L_cci=float(rng.uniform(0.5, 8.0)),
        V_cci=float(rng.uniform(0.05, 0.5)),
        c_cci=float(rng.uniform(0.005, 0.05)),
        L_vpn=float(rng.uniform(0.05, 0.5)),
        vpn_tier=tier,
        D=int(rng.integers(0, 30)),
        T_cci=int(rng.integers(1, 60)),
        h=int(rng.integers(1, 60)),
        theta1=float(rng.uniform(0.8, 1.0)),
        theta2=float(rng.uniform(1.0, 1.25)),
    )


def _random_demand(rng: np.random.Generator, n: int, T: int) -> np.ndarray:
    """Regime-switching rows so the FSMs actually transition."""
    d = np.empty((n, T))
    for i in range(n):
        base = rng.uniform(0, 400)
        row = np.full(T, base)
        for _ in range(int(rng.integers(1, 6))):
            a, b = np.sort(rng.integers(0, T, size=2))
            row[a:b] = rng.uniform(0, 4000)
        d[i] = row * rng.uniform(0.8, 1.2, size=T)
    return d


def _policies_for(arrays, out, rng):
    """One instance of each policy kind over ``arrays``, forecast included
    (predictions = noisy forward means, coefficients fitted on the runtime's
    own emitted series — how they were derived is irrelevant to exactness)."""
    with enable_x64():
        tp = arrays.toggle
        n, T = out["vpn_cost"].shape
        pred = _random_demand(rng, n, T) * rng.uniform(0.3, 1.2)
        coef = np.asarray(
            fit_cost_coef(
                jnp.asarray(pred), jnp.asarray(out["vpn_cost"]),
                jnp.asarray(out["cci_cost"]),
            )
        )
        return [
            reactive_policy(tp),
            hysteresis_policy(tp, up_hold=int(rng.integers(1, 8)),
                              down_hold=int(rng.integers(1, 8))),
            forecast_gated_policy(tp, pred, margin=0.05, cost_coef=coef),
        ]


# ---------------------------------------------------------------------------
# The tentpole property: streaming == policy_scan, bit for bit
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_streaming_steps_match_policy_scan_bit_for_bit(seed):
    """Random links + regime-switching demand, all three policies: N
    streaming steps must equal one offline policy_scan on the identical
    per-hour cost series (the runtime's emitted columns), bit for bit."""
    rng = np.random.default_rng(seed)
    n, T = 3, int(rng.integers(150, 400))
    fleet = fleet_from_params([_random_params(rng) for _ in range(n)])
    demand = _random_demand(rng, n, T)
    with enable_x64():
        arrays = fleet.stack(jnp.float64)

    # Prime with a reactive pass to get the emitted cost series.
    rt = FleetRuntime(arrays, hours_per_month=fleet.hours_per_month)
    base = rt.run(demand)
    vpn, cci = base["vpn_cost"], base["cci_cost"]

    for pol in _policies_for(arrays, base, rng):
        rt = FleetRuntime(arrays, policy=pol,
                          hours_per_month=fleet.hours_per_month)
        out = rt.run(demand)
        # Identical pricing stage across policies (it is policy-independent).
        np.testing.assert_array_equal(out["vpn_cost"], vpn)
        np.testing.assert_array_equal(out["cci_cost"], cci)
        for i in range(n):
            with enable_x64():
                row_pol = jax.tree.map(lambda a: a[i], pol)
                ref = policy_scan(
                    row_pol, jnp.asarray(vpn[i]), jnp.asarray(cci[i])
                )
            np.testing.assert_array_equal(out["x"][i], np.asarray(ref["x"]))
            np.testing.assert_array_equal(
                out["state"][i], np.asarray(ref["state"])
            )
            # Window sums are part of the contract too (prefix-ring == cumsum).
            np.testing.assert_array_equal(
                out["r_vpn"][i], np.asarray(ref["r_vpn"])
            )


# ---------------------------------------------------------------------------
# End-to-end vs the jitted offline engines (sampled scenarios)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1])
def test_streaming_matches_plan_fleet(seed):
    sc = build_fleet_scenario(8, horizon=600, history_hours=300, seed=seed)
    with enable_x64():
        arrays = sc.fleet.stack(jnp.float64)
    hpm = sc.fleet.hours_per_month

    plan = plan_fleet(sc.fleet, sc.demand)
    out = FleetRuntime(sc.fleet).run(sc.demand)
    np.testing.assert_array_equal(out["x"], np.asarray(plan["x"]))
    np.testing.assert_array_equal(out["state"], np.asarray(plan["state"]))
    np.testing.assert_allclose(
        out["vpn_cost"], np.asarray(plan["vpn_hourly"]), rtol=1e-12
    )

    with enable_x64():
        hy = make_policy("hysteresis", arrays.toggle)
    hplan = plan_fleet(arrays, sc.demand, policy=hy, hours_per_month=hpm)
    hout = FleetRuntime(arrays, policy=hy, hours_per_month=hpm).run(sc.demand)
    np.testing.assert_array_equal(hout["x"], np.asarray(hplan["x"]))

    fpol = forecast_fleet_policy(
        arrays, sc.demand, sc.history, steps=30, hours_per_month=hpm
    )
    fplan = plan_fleet(arrays, sc.demand, policy=fpol, hours_per_month=hpm)
    fout = FleetRuntime(arrays, policy=fpol, hours_per_month=hpm).run(sc.demand)
    np.testing.assert_array_equal(fout["x"], np.asarray(fplan["x"]))


@pytest.mark.parametrize("seed", [0, 1])
def test_streaming_matches_plan_topology(seed):
    sc = build_topology_scenario(
        10, n_facilities=3, horizon=600, history_hours=300, seed=seed
    )
    routing = optimize_routing(sc.topo, sc.demand)
    hpm = sc.topo.hours_per_month
    with enable_x64():
        arrays = sc.topo.stack(routing, jnp.float64)

    plan = plan_topology(arrays, sc.demand, hours_per_month=hpm)
    out = FleetRuntime(arrays, hours_per_month=hpm).run(sc.demand)
    np.testing.assert_array_equal(out["x"], np.asarray(plan["x"]))
    np.testing.assert_array_equal(out["state"], np.asarray(plan["state"]))
    np.testing.assert_allclose(
        out["cci_cost"], np.asarray(plan["cci_hourly"]), rtol=1e-12
    )

    fpol = forecast_topology_policy(
        arrays, sc.demand, sc.history, steps=30, hours_per_month=hpm
    )
    fplan = plan_topology(arrays, sc.demand, policy=fpol, hours_per_month=hpm)
    fout = FleetRuntime(arrays, policy=fpol, hours_per_month=hpm).run(sc.demand)
    np.testing.assert_array_equal(fout["x"], np.asarray(fplan["x"]))


def test_streaming_spec_entry_points_and_reset():
    """Spec-level construction (fleet + topology), mid-stream determinism:
    reset() replays identically; t tracks ticks."""
    sc = build_topology_scenario(6, n_facilities=2, horizon=200, seed=5)
    routing = optimize_routing(sc.topo, sc.demand)
    rt = FleetRuntime(sc.topo, routing=routing)
    a = rt.run(sc.demand)
    assert rt.t == sc.demand.shape[1]
    rt.reset()
    assert rt.t == 0
    b = rt.run(sc.demand)
    np.testing.assert_array_equal(a["x"], b["x"])
    with pytest.raises(AssertionError, match="routing"):
        FleetRuntime(sc.topo)


def test_month_boundary_streaming():
    """Short billing months force several within-stream tier resets; the
    streaming tier state must match the offline monthly_cumsum exactly.

    Pre-stacked arrays on purpose: with a FleetSpec both plan_fleet and
    FleetRuntime take hours_per_month from the spec (730 — no boundary
    inside 260 hours), silently ignoring the kwarg."""
    rng = np.random.default_rng(7)
    fleet = fleet_from_params([_random_params(rng) for _ in range(3)])
    demand = _random_demand(rng, 3, 260)
    with enable_x64():
        arrays = fleet.stack(jnp.float64)
    plan = plan_fleet(arrays, demand, hours_per_month=48)
    out = FleetRuntime(arrays, hours_per_month=48).run(demand)
    assert FleetRuntime(arrays, hours_per_month=48).hours_per_month == 48
    np.testing.assert_array_equal(out["x"], np.asarray(plan["x"]))
    np.testing.assert_allclose(
        out["vpn_cost"], np.asarray(plan["vpn_hourly"]), rtol=1e-12
    )
    # And the boundary really is exercised: tier positions reset at 48/96/...
    assert np.any(np.diff(np.asarray(plan["vpn_hourly"])[:, 47:49], axis=1) != 0)


# ---------------------------------------------------------------------------
# Live re-routing: reroute() == offline replay_plan_topology, bit for bit
# ---------------------------------------------------------------------------


def _alternative_routing(topo, r0, rng, max_moved=6):
    """A valid RoutingPlan that moves a few pairs to other candidate ports."""
    idx = np.asarray(r0.primary).copy()
    moved = 0
    for i, pr in enumerate(topo.pairs):
        others = [c for c in pr.candidates if c != idx[i]]
        if others and moved < max_moved and rng.random() < 0.8:
            idx[i] = int(rng.choice(others))
            moved += 1
    return topo.plan(idx), moved


@given(seed=st.integers(0, 10_000))
@settings(max_examples=4, deadline=None)
def test_reroute_matches_offline_replay_bit_for_bit(seed):
    """The tentpole's re-routing contract: streaming with reroute() at hour
    s equals an offline replay that applies the same routing at the same
    hour — decisions bit-for-bit over the WHOLE horizon (window sums near
    the swap mix old- and new-routing hours identically on both sides),
    for reactive, hysteresis and forecast-replay policies."""
    from repro.fleet.plan import replay_plan_topology

    rng = np.random.default_rng(seed)
    sc = build_topology_scenario(
        8, n_facilities=3, horizon=int(rng.integers(250, 450)), seed=seed
    )
    r0 = optimize_routing(sc.topo, sc.demand)
    r1, moved = _alternative_routing(sc.topo, r0, rng)
    if moved == 0:
        return  # no alternative candidates sampled — nothing to swap
    T = sc.demand.shape[1]
    s = int(rng.integers(50, T - 50))
    hpm = sc.topo.hours_per_month
    with enable_x64():
        arrays = sc.topo.stack(r0, jnp.float64)

    base = FleetRuntime(arrays, hours_per_month=hpm).run(sc.demand)
    for pol in _policies_for(arrays, base, rng):
        rt = FleetRuntime(arrays, policy=pol, hours_per_month=hpm)
        outs = []
        for t in range(T):
            if t == s:
                rt.reroute(r1)
            outs.append(rt.step(sc.demand[:, t]))
        x = np.stack([o["x"] for o in outs], axis=1)
        state = np.stack([o["state"] for o in outs], axis=1)
        replay = replay_plan_topology(
            arrays, sc.demand, [(0, r0), (s, r1)],
            policy=pol, hours_per_month=hpm,
        )
        np.testing.assert_array_equal(x, np.asarray(replay["x"]))
        np.testing.assert_array_equal(state, np.asarray(replay["state"]))


@given(seed=st.integers(0, 10_000))
@settings(max_examples=3, deadline=None)
def test_obs_on_off_decisions_bit_exact(seed):
    """Observability is a pure CONSUMER of the tick: with the device metrics
    ring in the carry (small drain cadence so drains actually interleave),
    tracing, monitors and divergence recording all on, every decision — and
    the realized cost — equals the obs-off stream bit for bit, for all three
    policies, across a mid-stream reroute(). And the honest stream passes
    every contract monitor."""
    from repro.obs import ObsConfig

    rng = np.random.default_rng(seed)
    sc = build_topology_scenario(
        8, n_facilities=3, horizon=int(rng.integers(180, 320)), seed=seed
    )
    r0 = optimize_routing(sc.topo, sc.demand)
    r1, moved = _alternative_routing(sc.topo, r0, rng)
    T = sc.demand.shape[1]
    s = int(rng.integers(40, T - 40))
    hpm = sc.topo.hours_per_month
    with enable_x64():
        arrays = sc.topo.stack(r0, jnp.float64)

    base = FleetRuntime(arrays, hours_per_month=hpm).run(sc.demand)
    for pol in _policies_for(arrays, base, rng):

        def stream(obs):
            rt = FleetRuntime(arrays, policy=pol, hours_per_month=hpm, obs=obs)
            outs = []
            for t in range(T):
                if moved and t == s:
                    rt.reroute(r1)
                outs.append(rt.step(sc.demand[:, t]))
            return rt, {
                k: np.stack([o[k] for o in outs], axis=1)
                for k in ("x", "state", "cost")
            }

        _, plain = stream(None)
        ort, traced = stream(ObsConfig(cadence=7, divergence=True))
        np.testing.assert_array_equal(plain["x"], traced["x"])
        np.testing.assert_array_equal(plain["state"], traced["state"])
        np.testing.assert_array_equal(plain["cost"], traced["cost"])
        ort.obs_check(final=True)
        rep = ort.obs_report()
        assert rep.hours == T and rep.violations == []


@given(seed=st.integers(0, 10_000))
@settings(max_examples=3, deadline=None)
def test_step_many_chunking_bit_exact(seed):
    """The chunked-stepping contract: ``step_many`` over any chunking of the
    demand stream equals per-tick ``step()`` BIT-EXACTLY — decisions, window
    sums, costs, and the carried billing prefixes — for all three policies,
    K in {1, 7, 24}, across a reroute() at a chunk boundary, with obs off
    and on (drain cadence a chunk multiple), and interleaved with a
    per-tick ragged tail."""
    from repro.obs import ObsConfig

    rng = np.random.default_rng(seed)
    sc = build_topology_scenario(
        8, n_facilities=3, horizon=int(rng.integers(210, 300)), seed=seed
    )
    r0 = optimize_routing(sc.topo, sc.demand)
    r1, moved = _alternative_routing(sc.topo, r0, rng)
    T = sc.demand.shape[1]
    s = 168  # chunk boundary for every K in {1, 7, 24} (168 = 7 * 24)
    hpm = sc.topo.hours_per_month
    with enable_x64():
        arrays = sc.topo.stack(r0, jnp.float64)

    fields = ("x", "state", "r_vpn", "r_cci", "vpn_cost", "cci_cost", "cost")
    base = FleetRuntime(arrays, hours_per_month=hpm).run(sc.demand)
    for pol in _policies_for(arrays, base, rng):
        # Per-tick reference stream (reroute at hour s).
        rt = FleetRuntime(arrays, policy=pol, hours_per_month=hpm)
        ref = []
        for t in range(T):
            if moved and t == s:
                rt.reroute(r1)
            ref.append(rt.step(sc.demand[:, t]))
        want = {f: np.stack([o[f] for o in ref], axis=1) for f in fields}
        want_state = rt._state

        for K in (1, 7, 24):
            for obs in (None, ObsConfig(cadence=3 * K, divergence=True)):
                rt2 = FleetRuntime(arrays, policy=pol,
                                   hours_per_month=hpm, obs=obs)
                outs, t = [], 0
                while t + K <= T:
                    if moved and t == s:
                        rt2.reroute(r1)
                    o = rt2.step_many(sc.demand[:, t:t + K])
                    outs.append({f: o[f] for f in fields})
                    t += K
                while t < T:  # ragged tail: chunked and per-tick interleave
                    if moved and t == s:
                        rt2.reroute(r1)
                    o = rt2.step(sc.demand[:, t])
                    outs.append({f: np.asarray(o[f])[:, None]
                                 for f in fields})
                    t += 1
                got = {f: np.concatenate([o[f] for o in outs], axis=1)
                       for f in fields}
                ctx = f"K={K} obs={'on' if obs else 'off'}"
                for f in fields:
                    np.testing.assert_array_equal(
                        got[f], want[f], err_msg=f"{ctx}:{f}"
                    )
                # Carried billing prefixes resync identically at boundaries.
                for f in ("vpn_pref", "cci_pref", "dcum", "dcum_month"):
                    np.testing.assert_array_equal(
                        getattr(rt2._state, f), getattr(want_state, f),
                        err_msg=f"{ctx}:{f}",
                    )
                if obs is not None:
                    rt2.obs_check(final=True)
                    rep = rt2.obs_report()
                    assert rep.hours == T and rep.violations == []


def test_replay_single_segment_is_plan_topology():
    """A one-entry schedule must reproduce plan_topology bit-for-bit (the
    replay oracle degenerates to the offline planner)."""
    from repro.fleet.plan import plan_topology, replay_plan_topology

    sc = build_topology_scenario(8, n_facilities=3, horizon=400, seed=2)
    r0 = optimize_routing(sc.topo, sc.demand)
    hpm = sc.topo.hours_per_month
    with enable_x64():
        arrays = sc.topo.stack(r0, jnp.float64)
    plan = plan_topology(arrays, sc.demand, hours_per_month=hpm)
    rep = replay_plan_topology(arrays, sc.demand, [(0, r0)], hours_per_month=hpm)
    np.testing.assert_array_equal(np.asarray(rep["x"]), np.asarray(plan["x"]))
    np.testing.assert_array_equal(
        np.asarray(rep["state"]), np.asarray(plan["state"])
    )
    np.testing.assert_array_equal(
        np.asarray(rep["toggle_cost"]), np.asarray(plan["toggle_cost"])
    )


def test_reroute_guards_and_modes_mapping():
    """reroute() is topology-only, validates against the spec, and modes()
    maps port states onto PAIRS through the current routing."""
    from repro.fleet.plan import build_reroute_scenario

    sc = build_reroute_scenario(horizon=300, shift_hour=150, seed=0)
    rt = FleetRuntime(sc.topo, routing=sc.topo.plan([0, 0, 1]))
    out = rt.step(sc.demand[:, 0])
    modes = rt.modes(out)
    assert len(modes) == 3  # per PAIR, not per port
    states = np.asarray(out["state"])
    from repro.core.planner import collective_mode

    assert modes == [collective_mode(int(states[m])) for m in (0, 0, 1)]
    np.testing.assert_array_equal(rt.port_occupancy(), [2.0, 1.0])
    rt.reroute(sc.topo.plan([0, 0, 0]))
    np.testing.assert_array_equal(rt.port_occupancy(), [3.0, 0.0])
    with pytest.raises(AssertionError, match="non-candidate"), \
            pytest.warns(DeprecationWarning):
        rt.reroute([1, 0, 0])  # pair 0's only candidate is port 0
    with pytest.raises(AssertionError, match="non-candidate"), \
            pytest.warns(DeprecationWarning):
        # The legacy matrix form goes through the SAME candidate validation.
        rt.reroute(np.array([[0.0, 1.0, 1.0], [1.0, 0.0, 0.0]]))
    with pytest.raises(AssertionError, match="one-hot"), \
            pytest.warns(DeprecationWarning):
        rt.reroute(np.ones((2, 3)))
    fleet_rt = FleetRuntime(_planner_fleet())
    with pytest.raises(AssertionError, match="topology"):
        fleet_rt.reroute([0, 0])
    assert fleet_rt.modes(fleet_rt.step(np.zeros(2))) == ["compressed"] * 2


def test_reroute_demo_scenario_realizes_savings():
    """The CI demo's core claim, in-tree: live re-routing onto the freed
    hub port beats the frozen day-one routing on realized streamed cost."""
    from repro.fleet.plan import build_reroute_scenario

    sc = build_reroute_scenario(horizon=1400, shift_hour=500, seed=1)
    r0 = optimize_routing(sc.topo, sc.demand[:, :168])
    assert list(r0.primary) == [0, 0, 1]  # hub full -> hot pair spills

    def run(live):
        rt = FleetRuntime(sc.topo, routing=r0)
        cost = 0.0
        for t in range(sc.demand.shape[1]):
            if live and t > 0 and t % 24 == 0:
                seen = sc.demand[:, max(0, t - 168):t].mean(axis=1)
                r_new = optimize_routing(sc.topo, mean_demand=seen)
                if not np.array_equal(r_new.primary, rt.routing_plan.primary):
                    rt.reroute(r_new)
            cost += float(rt.step(sc.demand[:, t])["cost"].sum())
        return cost, rt

    frozen, _ = run(False)
    lively, rt = run(True)
    assert lively < frozen
    np.testing.assert_array_equal(rt.port_occupancy(), [3.0, 0.0])


# ---------------------------------------------------------------------------
# Live-SSM forecast mode (causal, endogenous-capable)
# ---------------------------------------------------------------------------


def test_live_forecast_mode_matches_pinned_replay():
    """The carried SSM state must reproduce the offline forecaster's causal
    prediction columns: with the coefficients pinned, live streaming equals
    the offline plan on the replayed predictions."""
    from repro.fleet.policy import forecast_horizon_hours, forecast_port_demand

    sc = build_fleet_scenario(6, horizon=400, history_hours=300, seed=3)
    hpm = sc.fleet.hours_per_month
    with enable_x64():
        arrays = sc.fleet.stack(jnp.float64)
    pol, fc = streaming_forecast_policy(
        arrays, sc.history, steps=30, hours_per_month=hpm
    )
    out = FleetRuntime(
        arrays, policy=pol, forecaster=fc, hours_per_month=hpm
    ).run(sc.demand)

    cap = np.asarray(arrays.capacity)[:, None]
    clip = lambda d: np.minimum(np.asarray(d, np.float64), cap)
    pred = forecast_port_demand(
        clip(sc.history), clip(sc.demand),
        forecast_horizon_hours(arrays.toggle), steps=30,
    )
    with enable_x64():
        replay = forecast_gated_policy(
            arrays.toggle, pred, margin=0.05, cost_coef=np.asarray(pol.cost_coef)
        )
    rplan = plan_fleet(arrays, sc.demand, policy=replay, hours_per_month=hpm)
    np.testing.assert_array_equal(out["x"], np.asarray(rplan["x"]))


def test_streaming_forecast_requires_cost_coef():
    rng = np.random.default_rng(0)
    fleet = fleet_from_params([_random_params(rng)])
    with enable_x64():
        arrays = fleet.stack(jnp.float64)
        pol = forecast_gated_policy(arrays.toggle, np.zeros((1, 100)))
    with pytest.raises(AssertionError, match="cost_coef"):
        FleetRuntime(arrays, policy=pol)


# ---------------------------------------------------------------------------
# Endogenous-demand actuation (ElasticFleetPlanner)
# ---------------------------------------------------------------------------


def _planner_fleet():
    """One cold link (stays on the compressed pay-per-GB path) and one hot
    link (leases)."""
    from repro.core.planner import dci_scenario

    return fleet_from_params([dci_scenario(), dci_scenario()])


def test_elastic_planner_modes_split_per_link():
    pl = ElasticFleetPlanner(_planner_fleet())
    modes = None
    for _ in range(1500):
        modes = pl.feed_hour(np.array([1e9, 200e12]))  # 1 GB vs 200 TB hourly
    rep = pl.report()
    assert modes == ["compressed", "hierarchical"]
    assert rep.on_fraction[0] == 0.0 and rep.on_fraction[1] > 0.5
    # Per-link realized costs beat the wrong static policy on each side.
    assert rep.total_cost <= rep.cost_always_cci
    assert rep.link_cost[1] < pl.cost_vpn_only[1]


def test_elastic_planner_matches_single_link_controller():
    """N=1 ElasticFleetPlanner == core's InterconnectPlanner on the same
    byte stream (same FSM decisions; costs equal to float tolerance — the
    single-link controller slides its window with add/subtract, the runtime
    with exact prefix differences)."""
    from repro.core.planner import InterconnectPlanner, dci_scenario

    rng = np.random.default_rng(11)
    gb = np.where(rng.random(2500) < 0.5, 40e3, 20.0)  # regime flips, GB/h
    single = InterconnectPlanner()
    fleetp = ElasticFleetPlanner(fleet_from_params([dci_scenario()]))
    modes_a, modes_b = [], []
    for v in gb:
        modes_a.append(single.feed_hour(v * 1e9))
        modes_b.append(fleetp.feed_hour(np.array([v * 1e9]))[0])
    assert modes_a == modes_b
    ra, rb = single.report(), fleetp.report()
    assert ra.total_cost == pytest.approx(rb.total_cost, rel=1e-9)
    assert ra.cost_always_vpn == pytest.approx(rb.cost_always_vpn, rel=1e-9)
    assert ra.cost_always_cci == pytest.approx(rb.cost_always_cci, rel=1e-9)
    assert ra.on_fraction == pytest.approx(float(rb.on_fraction[0]))


def test_fleet_planner_factory():
    from repro.core.planner import fleet_planner

    pl = fleet_planner(_planner_fleet())
    assert isinstance(pl, ElasticFleetPlanner)


def test_elastic_planner_per_port_topology_mode():
    """Per-port actuation: feed per-PAIR bytes, get per-pair modes mapped
    through the routing; the report carries per-PORT lease occupancy and
    per-pair wire-byte savings instead of assuming one link per row."""
    from repro.core.pricing import flat_rate
    from repro.fleet.plan import PairSpec, PortSpec, TopologySpec

    mk_port = lambda n, f: PortSpec(
        name=n, facility=f, cloud="aws", L_cci=4.55, V_cci=0.1,
        c_cci=0.002, D=6, T_cci=12, h=12,
    )
    pairs = tuple(
        PairSpec(f"pr{i}", "gcp", "aws", 0.105, flat_rate(0.1),
                 candidates=(0, 1))
        for i in range(3)
    )
    topo = TopologySpec(ports=(mk_port("hub", "f0"), mk_port("idle", "f1")),
                        pairs=pairs)
    pl = ElasticFleetPlanner(topo, routing=topo.plan([0, 0, 1]))
    assert pl.topology
    np.testing.assert_array_equal(pl.sync_groups(), [0, 0, 1])
    traffic = np.array([5e12, 5e12, 1e9])  # two hot pairs share the hub
    modes = None
    for _ in range(200):
        modes = pl.feed_hour(traffic)
    assert modes == ["hierarchical", "hierarchical", "compressed"]
    rep = pl.report()
    np.testing.assert_array_equal(rep.port_occupancy, [2.0, 1.0])
    assert rep.on_fraction.shape == (2,)        # per PORT
    assert rep.pair_gb_saved.shape == (3,)      # per PAIR
    # The cold pair keeps compressing all 200 hours; the hot pairs only
    # during the provisioning window — per-GB savings must reflect that.
    frac_saved = rep.pair_gb_saved / (rep.pair_gb + rep.pair_gb_saved)
    assert frac_saved[2] > frac_saved[0]
    assert 0 < rep.wire_savings_fraction < 1
    # Shared lease: the hub port's CCI counterfactual charges ONE lease for
    # two pairs — L + 2V + c·(d1+d2) per hour, not 2L (the per-link view).
    gb = traffic / 1e9
    shared_hour = 4.55 + 2 * 0.1 + 0.002 * (gb[0] + gb[1])
    assert pl.cost_cci_only[0] == pytest.approx(rep.hours * shared_hour, rel=1e-9)
    # Re-routing re-targets actuation next tick.
    pl.runtime.reroute(topo.plan([0, 0, 0]))
    modes = pl.feed_hour(traffic)
    np.testing.assert_array_equal(pl.sync_groups(), [0, 0, 0])
    assert modes[2] == "hierarchical"  # now rides the (ON) hub port


# ---------------------------------------------------------------------------
# Collective actuation: link modes select the int8 vs hierarchical path
# ---------------------------------------------------------------------------


def test_sync_wire_bytes_compression_ratio():
    from repro.dist.collectives import sync_wire_bytes

    grads = {"w": jnp.zeros((256, 256), jnp.float32), "b": jnp.zeros((256,), jnp.float32)}
    full = sync_wire_bytes(grads, "hierarchical")
    comp = sync_wire_bytes(grads, "compressed")
    assert full == (256 * 256 + 256) * 4
    # int8 payload + one f32 scale per row: a hair under 4x.
    assert 3.5 < full / comp <= 4.0


def test_link_modes_actuate_sync_grads():
    """Two links on one mesh: the 'hierarchical' link syncs exactly like the
    full-precision path, the 'compressed' link goes through int8+error
    feedback (approximate, carries a residual, ~4x fewer billed bytes)."""
    script = """
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_host_mesh
        from repro.dist.collectives import fleet_sync_grads, sync_grads

        mesh = make_host_mesh(pod=2, data=2, model=2)
        rng = np.random.default_rng(0)
        grads = [
            {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)}
            for _ in range(2)
        ]
        modes = ["hierarchical", "compressed"]
        synced, errs, billed = fleet_sync_grads(grads, mesh, modes)
        # Link 0: exact full-precision hierarchical sync, no residual.
        ref0, _ = sync_grads(grads[0], mesh, mode="hierarchical")
        np.testing.assert_array_equal(
            np.asarray(synced[0]["w"]), np.asarray(ref0["w"])
        )
        assert errs[0] is None
        # Link 1: int8 path — approximate, residual returned, ~4x fewer bytes.
        a = np.asarray(grads[1]["w"]); b = np.asarray(synced[1]["w"])
        assert np.max(np.abs(a - b)) < np.abs(a).max() / 32
        assert errs[1] is not None
        assert 3.0 < billed[0] / billed[1] <= 4.0

        # Shared sync domains (per-port topology actuation): pairs on one
        # leased port sync in ONE call — results and per-pair billed bytes
        # identical to the ungrouped path.
        grads4 = [
            {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)}
            for _ in range(4)
        ]
        modes4 = ["hierarchical", "hierarchical", "compressed", "compressed"]
        groups = [7, 7, 7, 9]  # pairs 0+1 share port 7's leased domain
        gs, ge, gb = fleet_sync_grads(grads4, mesh, modes4, groups=groups)
        us, ue, ub = fleet_sync_grads(grads4, mesh, modes4)
        for i in range(4):
            np.testing.assert_array_equal(
                np.asarray(gs[i]["w"]), np.asarray(us[i]["w"])
            )
        assert gb == ub
        assert ge[0] is None and ge[2] is not None
        # Carried residuals survive a re-grouping (post-reroute step).
        gs2, ge2, _ = fleet_sync_grads(
            grads4, mesh, modes4, ge, groups=[7, 9, 9, 9]
        )
        us2, ue2, _ = fleet_sync_grads(grads4, mesh, modes4, ue)
        for i in range(4):
            np.testing.assert_array_equal(
                np.asarray(gs2[i]["w"]), np.asarray(us2[i]["w"])
            )
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    assert "OK" in out.stdout
