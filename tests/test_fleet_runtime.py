"""Streaming fleet runtime tests (the tentpole's bit-exactness contract).

The load-bearing property: N incremental ``FleetRuntime.step`` calls
reproduce one offline ``policy_scan`` DECISION-BIT-EXACTLY for all three
toggle policies. The airtight form pins the per-hour mode-cost series to the
runtime's own emitted columns (the same pinning contract
``plan_topology_reference`` documents): the runtime's carried prefix-ring
window state must then replicate ``policy_scan``'s float64 ``np.cumsum``
windows and FSM transitions exactly, over random windows/delays/thresholds
and regime-switching demand. Sampled-scenario tests additionally check the
streaming pricing stage against the jitted ``plan_fleet``/``plan_topology``
engines end-to-end (both policies' decisions and the cost series), plus the
live-SSM forecast mode, the endogenous-demand planner, and the collective
actuation path (int8 vs hierarchical selected by link modes).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.pricing import CostParams, TieredRate
from repro.fleet import (
    ElasticFleetPlanner,
    FleetRuntime,
    build_fleet_scenario,
    build_topology_scenario,
    forecast_fleet_policy,
    forecast_gated_policy,
    forecast_topology_policy,
    hysteresis_policy,
    make_policy,
    optimize_routing,
    plan_fleet,
    plan_topology,
    policy_scan,
    reactive_policy,
    streaming_forecast_policy,
)
from repro.fleet.policy import fit_cost_coef
from repro.fleet.spec import fleet_from_params

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _random_params(rng: np.random.Generator) -> CostParams:
    k = int(rng.integers(1, 4))
    bounds = np.sort(rng.uniform(50, 5000, size=k))
    rates = np.sort(rng.uniform(0.02, 0.2, size=k))[::-1]
    tier = TieredRate(tuple(bounds[:-1]) + (np.inf,), tuple(rates))
    return CostParams(
        L_cci=float(rng.uniform(0.5, 8.0)),
        V_cci=float(rng.uniform(0.05, 0.5)),
        c_cci=float(rng.uniform(0.005, 0.05)),
        L_vpn=float(rng.uniform(0.05, 0.5)),
        vpn_tier=tier,
        D=int(rng.integers(0, 30)),
        T_cci=int(rng.integers(1, 60)),
        h=int(rng.integers(1, 60)),
        theta1=float(rng.uniform(0.8, 1.0)),
        theta2=float(rng.uniform(1.0, 1.25)),
    )


def _random_demand(rng: np.random.Generator, n: int, T: int) -> np.ndarray:
    """Regime-switching rows so the FSMs actually transition."""
    d = np.empty((n, T))
    for i in range(n):
        base = rng.uniform(0, 400)
        row = np.full(T, base)
        for _ in range(int(rng.integers(1, 6))):
            a, b = np.sort(rng.integers(0, T, size=2))
            row[a:b] = rng.uniform(0, 4000)
        d[i] = row * rng.uniform(0.8, 1.2, size=T)
    return d


def _policies_for(arrays, out, rng):
    """One instance of each policy kind over ``arrays``, forecast included
    (predictions = noisy forward means, coefficients fitted on the runtime's
    own emitted series — how they were derived is irrelevant to exactness)."""
    with enable_x64():
        tp = arrays.toggle
        n, T = out["vpn_cost"].shape
        pred = _random_demand(rng, n, T) * rng.uniform(0.3, 1.2)
        coef = np.asarray(
            fit_cost_coef(
                jnp.asarray(pred), jnp.asarray(out["vpn_cost"]),
                jnp.asarray(out["cci_cost"]),
            )
        )
        return [
            reactive_policy(tp),
            hysteresis_policy(tp, up_hold=int(rng.integers(1, 8)),
                              down_hold=int(rng.integers(1, 8))),
            forecast_gated_policy(tp, pred, margin=0.05, cost_coef=coef),
        ]


# ---------------------------------------------------------------------------
# The tentpole property: streaming == policy_scan, bit for bit
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_streaming_steps_match_policy_scan_bit_for_bit(seed):
    """Random links + regime-switching demand, all three policies: N
    streaming steps must equal one offline policy_scan on the identical
    per-hour cost series (the runtime's emitted columns), bit for bit."""
    rng = np.random.default_rng(seed)
    n, T = 3, int(rng.integers(150, 400))
    fleet = fleet_from_params([_random_params(rng) for _ in range(n)])
    demand = _random_demand(rng, n, T)
    with enable_x64():
        arrays = fleet.stack(jnp.float64)

    # Prime with a reactive pass to get the emitted cost series.
    rt = FleetRuntime(arrays, hours_per_month=fleet.hours_per_month)
    base = rt.run(demand)
    vpn, cci = base["vpn_cost"], base["cci_cost"]

    for pol in _policies_for(arrays, base, rng):
        rt = FleetRuntime(arrays, policy=pol,
                          hours_per_month=fleet.hours_per_month)
        out = rt.run(demand)
        # Identical pricing stage across policies (it is policy-independent).
        np.testing.assert_array_equal(out["vpn_cost"], vpn)
        np.testing.assert_array_equal(out["cci_cost"], cci)
        for i in range(n):
            with enable_x64():
                row_pol = jax.tree.map(lambda a: a[i], pol)
                ref = policy_scan(
                    row_pol, jnp.asarray(vpn[i]), jnp.asarray(cci[i])
                )
            np.testing.assert_array_equal(out["x"][i], np.asarray(ref["x"]))
            np.testing.assert_array_equal(
                out["state"][i], np.asarray(ref["state"])
            )
            # Window sums are part of the contract too (prefix-ring == cumsum).
            np.testing.assert_array_equal(
                out["r_vpn"][i], np.asarray(ref["r_vpn"])
            )


# ---------------------------------------------------------------------------
# End-to-end vs the jitted offline engines (sampled scenarios)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1])
def test_streaming_matches_plan_fleet(seed):
    sc = build_fleet_scenario(8, horizon=600, history_hours=300, seed=seed)
    with enable_x64():
        arrays = sc.fleet.stack(jnp.float64)
    hpm = sc.fleet.hours_per_month

    plan = plan_fleet(sc.fleet, sc.demand)
    out = FleetRuntime(sc.fleet).run(sc.demand)
    np.testing.assert_array_equal(out["x"], np.asarray(plan["x"]))
    np.testing.assert_array_equal(out["state"], np.asarray(plan["state"]))
    np.testing.assert_allclose(
        out["vpn_cost"], np.asarray(plan["vpn_hourly"]), rtol=1e-12
    )

    with enable_x64():
        hy = make_policy("hysteresis", arrays.toggle)
    hplan = plan_fleet(arrays, sc.demand, policy=hy, hours_per_month=hpm)
    hout = FleetRuntime(arrays, policy=hy, hours_per_month=hpm).run(sc.demand)
    np.testing.assert_array_equal(hout["x"], np.asarray(hplan["x"]))

    fpol = forecast_fleet_policy(
        arrays, sc.demand, sc.history, steps=30, hours_per_month=hpm
    )
    fplan = plan_fleet(arrays, sc.demand, policy=fpol, hours_per_month=hpm)
    fout = FleetRuntime(arrays, policy=fpol, hours_per_month=hpm).run(sc.demand)
    np.testing.assert_array_equal(fout["x"], np.asarray(fplan["x"]))


@pytest.mark.parametrize("seed", [0, 1])
def test_streaming_matches_plan_topology(seed):
    sc = build_topology_scenario(
        10, n_facilities=3, horizon=600, history_hours=300, seed=seed
    )
    routing = optimize_routing(sc.topo, sc.demand)
    hpm = sc.topo.hours_per_month
    with enable_x64():
        arrays = sc.topo.stack(routing, jnp.float64)

    plan = plan_topology(arrays, sc.demand, hours_per_month=hpm)
    out = FleetRuntime(arrays, hours_per_month=hpm).run(sc.demand)
    np.testing.assert_array_equal(out["x"], np.asarray(plan["x"]))
    np.testing.assert_array_equal(out["state"], np.asarray(plan["state"]))
    np.testing.assert_allclose(
        out["cci_cost"], np.asarray(plan["cci_hourly"]), rtol=1e-12
    )

    fpol = forecast_topology_policy(
        arrays, sc.demand, sc.history, steps=30, hours_per_month=hpm
    )
    fplan = plan_topology(arrays, sc.demand, policy=fpol, hours_per_month=hpm)
    fout = FleetRuntime(arrays, policy=fpol, hours_per_month=hpm).run(sc.demand)
    np.testing.assert_array_equal(fout["x"], np.asarray(fplan["x"]))


def test_streaming_spec_entry_points_and_reset():
    """Spec-level construction (fleet + topology), mid-stream determinism:
    reset() replays identically; t tracks ticks."""
    sc = build_topology_scenario(6, n_facilities=2, horizon=200, seed=5)
    routing = optimize_routing(sc.topo, sc.demand)
    rt = FleetRuntime(sc.topo, routing=routing)
    a = rt.run(sc.demand)
    assert rt.t == sc.demand.shape[1]
    rt.reset()
    assert rt.t == 0
    b = rt.run(sc.demand)
    np.testing.assert_array_equal(a["x"], b["x"])
    with pytest.raises(AssertionError, match="routing"):
        FleetRuntime(sc.topo)


def test_month_boundary_streaming():
    """Short billing months force several within-stream tier resets; the
    streaming tier state must match the offline monthly_cumsum exactly.

    Pre-stacked arrays on purpose: with a FleetSpec both plan_fleet and
    FleetRuntime take hours_per_month from the spec (730 — no boundary
    inside 260 hours), silently ignoring the kwarg."""
    rng = np.random.default_rng(7)
    fleet = fleet_from_params([_random_params(rng) for _ in range(3)])
    demand = _random_demand(rng, 3, 260)
    with enable_x64():
        arrays = fleet.stack(jnp.float64)
    plan = plan_fleet(arrays, demand, hours_per_month=48)
    out = FleetRuntime(arrays, hours_per_month=48).run(demand)
    assert FleetRuntime(arrays, hours_per_month=48).hours_per_month == 48
    np.testing.assert_array_equal(out["x"], np.asarray(plan["x"]))
    np.testing.assert_allclose(
        out["vpn_cost"], np.asarray(plan["vpn_hourly"]), rtol=1e-12
    )
    # And the boundary really is exercised: tier positions reset at 48/96/...
    assert np.any(np.diff(np.asarray(plan["vpn_hourly"])[:, 47:49], axis=1) != 0)


# ---------------------------------------------------------------------------
# Live-SSM forecast mode (causal, endogenous-capable)
# ---------------------------------------------------------------------------


def test_live_forecast_mode_matches_pinned_replay():
    """The carried SSM state must reproduce the offline forecaster's causal
    prediction columns: with the coefficients pinned, live streaming equals
    the offline plan on the replayed predictions."""
    from repro.fleet.policy import forecast_horizon_hours, forecast_port_demand

    sc = build_fleet_scenario(6, horizon=400, history_hours=300, seed=3)
    hpm = sc.fleet.hours_per_month
    with enable_x64():
        arrays = sc.fleet.stack(jnp.float64)
    pol, fc = streaming_forecast_policy(
        arrays, sc.history, steps=30, hours_per_month=hpm
    )
    out = FleetRuntime(
        arrays, policy=pol, forecaster=fc, hours_per_month=hpm
    ).run(sc.demand)

    cap = np.asarray(arrays.capacity)[:, None]
    clip = lambda d: np.minimum(np.asarray(d, np.float64), cap)
    pred = forecast_port_demand(
        clip(sc.history), clip(sc.demand),
        forecast_horizon_hours(arrays.toggle), steps=30,
    )
    with enable_x64():
        replay = forecast_gated_policy(
            arrays.toggle, pred, margin=0.05, cost_coef=np.asarray(pol.cost_coef)
        )
    rplan = plan_fleet(arrays, sc.demand, policy=replay, hours_per_month=hpm)
    np.testing.assert_array_equal(out["x"], np.asarray(rplan["x"]))


def test_streaming_forecast_requires_cost_coef():
    rng = np.random.default_rng(0)
    fleet = fleet_from_params([_random_params(rng)])
    with enable_x64():
        arrays = fleet.stack(jnp.float64)
        pol = forecast_gated_policy(arrays.toggle, np.zeros((1, 100)))
    with pytest.raises(AssertionError, match="cost_coef"):
        FleetRuntime(arrays, policy=pol)


# ---------------------------------------------------------------------------
# Endogenous-demand actuation (ElasticFleetPlanner)
# ---------------------------------------------------------------------------


def _planner_fleet():
    """One cold link (stays on the compressed pay-per-GB path) and one hot
    link (leases)."""
    from repro.core.planner import dci_scenario

    return fleet_from_params([dci_scenario(), dci_scenario()])


def test_elastic_planner_modes_split_per_link():
    pl = ElasticFleetPlanner(_planner_fleet())
    modes = None
    for _ in range(1500):
        modes = pl.feed_hour(np.array([1e9, 200e12]))  # 1 GB vs 200 TB hourly
    rep = pl.report()
    assert modes == ["compressed", "hierarchical"]
    assert rep.on_fraction[0] == 0.0 and rep.on_fraction[1] > 0.5
    # Per-link realized costs beat the wrong static policy on each side.
    assert rep.total_cost <= rep.cost_always_cci
    assert rep.link_cost[1] < pl.cost_vpn_only[1]


def test_elastic_planner_matches_single_link_controller():
    """N=1 ElasticFleetPlanner == core's InterconnectPlanner on the same
    byte stream (same FSM decisions; costs equal to float tolerance — the
    single-link controller slides its window with add/subtract, the runtime
    with exact prefix differences)."""
    from repro.core.planner import InterconnectPlanner, dci_scenario

    rng = np.random.default_rng(11)
    gb = np.where(rng.random(2500) < 0.5, 40e3, 20.0)  # regime flips, GB/h
    single = InterconnectPlanner()
    fleetp = ElasticFleetPlanner(fleet_from_params([dci_scenario()]))
    modes_a, modes_b = [], []
    for v in gb:
        modes_a.append(single.feed_hour(v * 1e9))
        modes_b.append(fleetp.feed_hour(np.array([v * 1e9]))[0])
    assert modes_a == modes_b
    ra, rb = single.report(), fleetp.report()
    assert ra.total_cost == pytest.approx(rb.total_cost, rel=1e-9)
    assert ra.cost_always_vpn == pytest.approx(rb.cost_always_vpn, rel=1e-9)
    assert ra.cost_always_cci == pytest.approx(rb.cost_always_cci, rel=1e-9)
    assert ra.on_fraction == pytest.approx(float(rb.on_fraction[0]))


def test_fleet_planner_factory():
    from repro.core.planner import fleet_planner

    pl = fleet_planner(_planner_fleet())
    assert isinstance(pl, ElasticFleetPlanner)


# ---------------------------------------------------------------------------
# Collective actuation: link modes select the int8 vs hierarchical path
# ---------------------------------------------------------------------------


def test_sync_wire_bytes_compression_ratio():
    from repro.dist.collectives import sync_wire_bytes

    grads = {"w": jnp.zeros((256, 256), jnp.float32), "b": jnp.zeros((256,), jnp.float32)}
    full = sync_wire_bytes(grads, "hierarchical")
    comp = sync_wire_bytes(grads, "compressed")
    assert full == (256 * 256 + 256) * 4
    # int8 payload + one f32 scale per row: a hair under 4x.
    assert 3.5 < full / comp <= 4.0


def test_link_modes_actuate_sync_grads():
    """Two links on one mesh: the 'hierarchical' link syncs exactly like the
    full-precision path, the 'compressed' link goes through int8+error
    feedback (approximate, carries a residual, ~4x fewer billed bytes)."""
    script = """
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_host_mesh
        from repro.dist.collectives import fleet_sync_grads, sync_grads

        mesh = make_host_mesh(pod=2, data=2, model=2)
        rng = np.random.default_rng(0)
        grads = [
            {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)}
            for _ in range(2)
        ]
        modes = ["hierarchical", "compressed"]
        synced, errs, billed = fleet_sync_grads(grads, mesh, modes)
        # Link 0: exact full-precision hierarchical sync, no residual.
        ref0, _ = sync_grads(grads[0], mesh, mode="hierarchical")
        np.testing.assert_array_equal(
            np.asarray(synced[0]["w"]), np.asarray(ref0["w"])
        )
        assert errs[0] is None
        # Link 1: int8 path — approximate, residual returned, ~4x fewer bytes.
        a = np.asarray(grads[1]["w"]); b = np.asarray(synced[1]["w"])
        assert np.max(np.abs(a - b)) < np.abs(a).max() / 32
        assert errs[1] is not None
        assert 3.0 < billed[0] / billed[1] <= 4.0
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    assert "OK" in out.stdout
