"""The typed routing currency: RoutingPlan round-trips + the legacy shim.

Two contracts:

* :class:`repro.fleet.routing.RoutingPlan` is self-consistent — index /
  matrix / operand forms round-trip losslessly, padding and path edits
  preserve identity, and validation rejects malformed plans;
* every public entry point that takes a routing accepts the legacy bare
  forms — ``(P,)`` port indices and ``(M, P)`` one-hot matrices — through
  :func:`repro.fleet.routing.as_routing_plan`, which must WARN
  (``DeprecationWarning`` naming the call site) and produce results
  IDENTICAL to the RoutingPlan spelling (the same shape as the
  ``repro.fleet`` facade shim test).
"""
import re
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.fleet.plan import (
    build_topology_report,
    build_topology_scenario,
    dedicated_fleet,
    optimize_routing,
    plan_topology,
    refine_routing,
    replay_plan_topology,
)
from repro.fleet.routing import RoutingOperand, RoutingPlan, as_routing_plan
from repro.fleet.stream import FleetRuntime


@pytest.fixture(scope="module")
def scenario():
    return build_topology_scenario(6, n_facilities=2, horizon=150, seed=3)


@pytest.fixture(scope="module")
def base_plan(scenario):
    return optimize_routing(scenario.topo, scenario.demand)


# ---------------------------------------------------------------------------
# RoutingPlan construction and round-trips
# ---------------------------------------------------------------------------


def test_from_indices_round_trip():
    idx = np.array([2, 0, 1, 0])
    p = RoutingPlan.from_indices(idx, 3)
    assert p.paths == ((2,), (0,), (1,), (0,))
    assert p.is_unicast and p.hop_depth == 1 and p.total_hops == 4
    np.testing.assert_array_equal(p.primary, idx)
    np.testing.assert_array_equal(p.port_indices(), idx)
    np.testing.assert_array_equal(np.asarray(p), idx)
    # Matrix view is the legacy one-hot; from_matrix round-trips it.
    assert p.matrix.shape == (3, 4)
    np.testing.assert_array_equal(p.matrix.sum(axis=0), np.ones(4))
    p2 = RoutingPlan.from_matrix(p.matrix)
    assert p2.paths == p.paths


def test_operand_round_trip_and_padding():
    p = RoutingPlan(paths=((0,), (1, 2), (0,)), n_ports=3)
    assert p.total_hops == 4 and p.n_legs == 4 and p.hop_depth == 2
    with enable_x64():
        op = p.operand(jnp.float64)
        assert isinstance(op, RoutingOperand)
        back = RoutingPlan.from_operand(op, 3, provenance="rt")
        assert back.paths == p.paths
        # pad_to() only grows the leg bound; decoded paths are unchanged.
        padded = p.pad_to(9)
        assert padded.n_legs == 9 and padded.paths == p.paths
        pop = padded.operand(jnp.float64)
        assert pop.leg_pair.shape == (9,)
        np.testing.assert_array_equal(
            np.asarray(pop.attach_w)[4:], np.zeros(5)
        )
        assert RoutingPlan.from_operand(pop, 3).paths == p.paths
    with pytest.raises(AssertionError):
        p.pad_to(3)  # below the tight bound


def test_replace_path_grows_leg_bound():
    p = RoutingPlan.from_indices([0, 1], 3)
    q = p.replace_path(0, (1, 2))
    assert q.paths == ((1, 2), (1,)) and q.n_legs == 3
    # An already-padded plan keeps its larger bound.
    r = p.pad_to(8).replace_path(0, (1, 2))
    assert r.n_legs == 8


def test_validation_rejects_malformed_plans():
    with pytest.raises(AssertionError, match="out of range"):
        RoutingPlan(paths=((3,),), n_ports=3)
    with pytest.raises(AssertionError, match="twice"):
        RoutingPlan(paths=((1, 1),), n_ports=3)
    with pytest.raises(AssertionError, match="empty"):
        RoutingPlan(paths=((),), n_ports=3)
    with pytest.raises(AssertionError, match="one-hot"):
        RoutingPlan.from_matrix(np.ones((2, 3)))


def test_tree_plan_has_no_index_view():
    p = RoutingPlan(paths=((0,), (1, 2)), n_ports=3, tree_rows=(1,))
    assert not p.is_unicast
    with pytest.raises(TypeError, match="tree rows"):
        p.port_indices()
    # primary still exposes the first hop (obs/actuation mapping).
    np.testing.assert_array_equal(p.primary, [0, 1])


def test_as_routing_plan_passthrough_is_silent(base_plan):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        got = as_routing_plan(base_plan, n_ports=base_plan.n_ports,
                              context="test")
    assert got is base_plan


# ---------------------------------------------------------------------------
# The legacy shim: every entry point warns AND matches the plan spelling
# ---------------------------------------------------------------------------


def _digest(x):
    """Flatten any result into comparable numpy leaves."""
    if isinstance(x, RoutingPlan):
        return {"paths": x.paths, "tree_rows": x.tree_rows}
    if isinstance(x, dict):
        return {k: _digest(v) for k, v in x.items()}
    if isinstance(x, (tuple, list)):
        return [_digest(v) for v in x]
    if isinstance(x, (jax.Array, np.ndarray)):
        return np.asarray(x)
    return x


def _case_stack(sc, routing):
    with enable_x64():
        op = sc.topo.stack(routing, jnp.float64).routing
    return {f: np.asarray(getattr(op, f)) for f in op._fields}


def _case_plan_topology(sc, routing):
    out = plan_topology(sc.topo, sc.demand, routing=routing)
    return {k: np.asarray(out[k]) for k in ("x", "toggle_cost")}


def _case_replay(sc, routing):
    plan = optimize_routing(sc.topo, sc.demand)
    with enable_x64():
        arrays = sc.topo.stack(plan, jnp.float64)
    out = replay_plan_topology(
        arrays, sc.demand, [(0, routing)],
        hours_per_month=sc.topo.hours_per_month,
    )
    return {k: np.asarray(out[k]) for k in ("x", "toggle_cost")}


def _case_runtime_init(sc, routing):
    rt = FleetRuntime(sc.topo, routing=routing)
    return _digest(rt.step_many(sc.demand[:, :24]))


def _case_runtime_reroute(sc, routing):
    rt = FleetRuntime(sc.topo, routing=optimize_routing(sc.topo, sc.demand))
    rt.step_many(sc.demand[:, :12])
    rt.reroute(routing)
    return _digest(rt.step_many(sc.demand[:, 12:24]))


def _case_report(sc, routing):
    out = plan_topology(sc.topo, sc.demand, routing=routing)
    rep = build_topology_report(sc, {k: np.asarray(v) for k, v in out.items()},
                                routing)
    return rep.totals


def _case_refine(sc, routing):
    refined, info = refine_routing(
        sc.topo, sc.demand, routing, max_moves=2
    )
    return {"paths": refined.paths, "cost": info["cost_after"]}


def _case_dedicated(sc, routing):
    fleet = dedicated_fleet(sc.topo, routing)
    return [(l.name, l.params.L_cci, l.params.c_cci) for l in fleet.links]


CASES = [
    ("TopologySpec.stack", _case_stack),
    ("plan_topology", _case_plan_topology),
    ("replay_plan_topology", _case_replay),
    ("FleetRuntime(routing=)", _case_runtime_init),
    ("FleetRuntime.reroute", _case_runtime_reroute),
    ("build_topology_report", _case_report),
    ("refine_routing", _case_refine),
    ("dedicated_fleet", _case_dedicated),
]


def _assert_same(a, b, ctx=""):
    assert type(a) is type(b) or (
        isinstance(a, (int, float)) and isinstance(b, (int, float))
    ), (ctx, type(a), type(b))
    if isinstance(a, dict):
        assert a.keys() == b.keys(), ctx
        for k in a:
            _assert_same(a[k], b[k], f"{ctx}.{k}")
    elif isinstance(a, np.ndarray):
        np.testing.assert_array_equal(a, b, err_msg=ctx)
    elif isinstance(a, (list, tuple)) and a and not isinstance(a[0], int):
        assert len(a) == len(b), ctx
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_same(x, y, f"{ctx}[{i}]")
    else:
        assert a == b, (ctx, a, b)


@pytest.mark.parametrize("context,case", CASES, ids=[c[0] for c in CASES])
@pytest.mark.parametrize("form", ["indices", "matrix"])
def test_legacy_routing_form_warns_and_matches(
    scenario, base_plan, context, case, form
):
    """Each legacy bare-array spelling: DeprecationWarning naming the call
    site, results identical to the RoutingPlan spelling."""
    legacy = (
        np.asarray(base_plan.primary) if form == "indices"
        else base_plan.matrix
    )
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        want = case(scenario, base_plan)
    with pytest.warns(DeprecationWarning, match=re.escape(context)):
        got = case(scenario, legacy)
    _assert_same(_digest(want), _digest(got), context)


def test_gateway_reroute_legacy_warns_and_matches(scenario, base_plan):
    """FleetGateway.reroute: the pooled-slot operand written through the
    legacy index form equals the RoutingPlan write, and warns."""
    from repro.gateway import FleetGateway, GatewayConfig, TenantSpec
    from repro.gateway.gateway import RuntimeConfig

    def run(routing):
        gw = FleetGateway(GatewayConfig(slots_per_bucket=2))
        gw.join("t", TenantSpec(
            spec=scenario.topo, demand=scenario.demand,
            config=RuntimeConfig(routing=base_plan),
        ))
        gw.tick()
        gw.reroute("t", routing)
        return [np.asarray(gw.tick()["t"]["x"]) for _ in range(3)]

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        want = run(base_plan)
    with pytest.warns(DeprecationWarning,
                      match=re.escape("FleetGateway.reroute")):
        got = run(np.asarray(base_plan.primary))
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
