"""Deliverable-(e) artifact guard: if a dry-run results directory exists,
every (arch × shape × mesh) cell must be present and either ok or
skipped-by-rule, with the roofline inputs populated. Skips when the sweep
hasn't been run (artifacts are generated, not committed source)."""
import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS
from repro.launch.dryrun import SHAPES, cell_supported

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


@pytest.mark.skipif(
    not os.path.isdir(DRYRUN_DIR) or not glob.glob(os.path.join(DRYRUN_DIR, "*.json")),
    reason="dry-run sweep not present (run repro.launch.dryrun first)",
)
def test_all_cells_present_and_green():
    missing, failed = [], []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
                if not os.path.exists(path):
                    missing.append((arch, shape, mesh))
                    continue
                rec = json.load(open(path))
                ok_expected, _ = cell_supported(arch, shape)
                if ok_expected:
                    if rec.get("status") != "ok":
                        failed.append((arch, shape, mesh, rec.get("status")))
                    else:
                        assert rec["hlo_flops_per_device"] > 0, (arch, shape, mesh)
                        assert "collectives" in rec and "memory" in rec
                else:
                    assert rec.get("status") == "skipped", (arch, shape, mesh)
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"
