"""The versioned ``repro.fleet`` facade: three namespaces, a declared
``__all__``, and every pre-namespace flat name still importable through a
shim that raises ``DeprecationWarning`` and resolves to the SAME object."""
import importlib
import warnings

import pytest

import repro.fleet as fleet
from repro.fleet import observe, plan, stream


def test_facade_declares_namespaces():
    assert fleet.__all__ == ["observe", "plan", "stream"]
    # The namespaces re-export with their own __all__ (documented surface).
    for ns in (plan, stream, observe):
        assert ns.__all__, ns.__name__
        for name in ns.__all__:
            assert hasattr(ns, name), (ns.__name__, name)


@pytest.mark.parametrize(
    "name", sorted(fleet._LEGACY_HOME), ids=lambda n: n
)
def test_every_legacy_flat_name_warns_and_resolves(name):
    """Each old ``from repro.fleet import X`` spelling keeps working for one
    release: it warns, and hands back the identical defining-module object."""
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = getattr(fleet, name)
    assert any(
        issubclass(x.category, DeprecationWarning) and name in str(x.message)
        for x in w
    ), f"{name} must raise DeprecationWarning"
    home = importlib.import_module(fleet._LEGACY_HOME[name])
    assert got is getattr(home, name)
    # And the same object is reachable warning-clean via its new namespace.
    ns = importlib.import_module(fleet._NAMESPACE_OF[fleet._LEGACY_HOME[name]])
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert getattr(ns, name) is got


def test_unknown_attribute_still_raises():
    with pytest.raises(AttributeError, match="no attribute"):
        fleet.definitely_not_a_fleet_name


def test_namespace_imports_are_warning_clean():
    """The migrated spellings must not trip the deprecation shims."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        from repro.fleet.plan import plan_fleet  # noqa: F401
        from repro.fleet.stream import FleetRuntime, RuntimeConfig  # noqa: F401
        from repro.fleet.observe import ContractViolation  # noqa: F401


def test_dir_lists_both_surfaces():
    names = dir(fleet)
    assert {"plan", "stream", "observe"} <= set(names)
    assert "plan_fleet" in names and "FleetRuntime" in names
