"""Tests for the Eq. (2) cost model (paper §V)."""
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st
import hypothesis.extra.numpy as hnp

import jax.numpy as jnp

from repro.core.costmodel import (
    cost_breakdown,
    evaluate_schedule,
    hourly_cost_series,
    hourly_cost_series_jnp,
    tiered_marginal_cost_np,
)
from repro.core.pricing import CostParams, flat_rate, make_scenario

P = make_scenario("gcp", "aws")


def demand_strategy(max_t=400, max_p=3):
    return hnp.arrays(
        dtype=np.float64,
        shape=st.tuples(st.integers(1, max_t), st.integers(1, max_p)),
        elements=st.floats(0, 1e4),
    )


@given(demand_strategy())
def test_cost_series_nonnegative_and_shapes(d):
    c = hourly_cost_series(P, d)
    T = d.shape[0]
    for arr in (c.vpn_lease, c.vpn_transfer, c.cci_lease, c.cci_transfer):
        assert arr.shape == (T,)
        assert (arr >= 0).all()


@given(demand_strategy(max_t=200))
def test_schedule_cost_interpolates(d):
    """All-VPN and all-CCI schedules bracket any mixed schedule... not in
    general — but evaluate_schedule must equal the sum of chosen sides."""
    c = hourly_cost_series(P, d)
    T = d.shape[0]
    rng = np.random.default_rng(42)
    x = rng.integers(0, 2, size=T)
    total = evaluate_schedule(P, d, x, costs=c)
    manual = float(np.sum(np.where(x == 1, c.cci, c.vpn)))
    assert total == pytest.approx(manual)


def test_monthly_tier_reset():
    """Tier position resets at month boundaries: hour-0-of-month traffic is
    billed at the first tier even after a huge previous month."""
    params = make_scenario("gcp", "aws")
    m = params.hours_per_month
    d = np.zeros(m + 1)
    d[0] = 5e6        # deep into the cheapest tier in month 0
    d[m - 1] = 100.0  # still billed at the last tier (cum 5e6)
    d[m] = 100.0      # new month: billed at the first tier again
    c = hourly_cost_series(params, d)
    rate_last = c.vpn_transfer[m - 1] / 100.0
    rate_reset = c.vpn_transfer[m] / 100.0
    assert rate_last == pytest.approx(params.vpn_tier.rates[-1])
    assert rate_reset == pytest.approx(params.vpn_tier.rates[0])


def test_tiered_vs_flat_vpn():
    """With a flat vpn tier, transfer cost is exactly rate * volume."""
    params = CostParams(4.55, 0.42, 0.02, 0.105, flat_rate(0.09))
    d = np.abs(np.random.default_rng(0).normal(100, 30, size=(500, 2)))
    c = hourly_cost_series(params, d)
    np.testing.assert_allclose(c.vpn_transfer, 0.09 * d.sum(axis=1), rtol=1e-12)


def test_cci_cost_is_flat_rate():
    d = np.abs(np.random.default_rng(1).normal(100, 30, size=(300,)))
    c = hourly_cost_series(P, d)
    np.testing.assert_allclose(c.cci_transfer, P.c_cci * d, rtol=1e-12)
    np.testing.assert_allclose(c.cci_lease, P.L_cci + P.V_cci)


def test_per_pair_tier_accumulation():
    """Tiers accumulate per pair: one pair at 2x rate hits cheap tiers sooner
    than two pairs at 1x rate each (same aggregate)."""
    params = make_scenario("gcp", "aws")
    T = 2000
    one = np.full((T, 1), 2000.0)
    two = np.full((T, 2), 1000.0)
    c1 = hourly_cost_series(params, one).vpn.sum()
    c2 = hourly_cost_series(params, two).vpn.sum()
    assert c1 < c2 - params.L_vpn * T * 0.5  # also pays one less lease


def test_breakdown_sums_to_total():
    d = np.abs(np.random.default_rng(2).normal(50, 20, size=(400, 2)))
    x = np.random.default_rng(3).integers(0, 2, size=400)
    b = cost_breakdown(P, d, x)
    assert b["total"] == pytest.approx(b["lease"] + b["transfer"])
    assert b["total"] == pytest.approx(evaluate_schedule(P, d, x))


@given(demand_strategy(max_t=300, max_p=2))
def test_jnp_matches_numpy(d):
    c = hourly_cost_series(P, d)
    cj = hourly_cost_series_jnp(P, jnp.asarray(d, jnp.float32))
    np.testing.assert_allclose(np.asarray(cj["vpn"]), c.vpn, rtol=2e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(cj["cci"]), c.cci, rtol=2e-3, atol=1e-2)


@given(
    start=st.floats(0, 1e6),
    add=hnp.arrays(np.float64, st.integers(1, 50), elements=st.floats(0, 1e4)),
)
def test_vectorized_tier_matches_scalar(start, add):
    tier = P.vpn_tier
    vec = tiered_marginal_cost_np(tier, np.full(add.shape, start), add)
    ref = np.array([tier.marginal_cost(start, a) for a in add])
    np.testing.assert_allclose(vec, ref, rtol=1e-12, atol=1e-12)
