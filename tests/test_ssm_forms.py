"""Equivalence of the recurrent / parallel / chunkwise SSM forms — the
correctness backbone of the xLSTM and Jamba cells (train uses parallel or
chunkwise, decode uses recurrent; they must be the same function)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.models import ssm

CFG_X = reduce_config(get_config("xlstm-1.3b"))
CFG_J = reduce_config(get_config("jamba-v0.1-52b"))
KEY = jax.random.PRNGKey(0)


def test_mlstm_chunkwise_matches_parallel():
    p = ssm.mlstm_init(KEY, CFG_X)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, CFG_X.d_model))
    y1, s1 = ssm._mlstm_parallel(CFG_X, p, x)
    for chunk in (8, 16, 48):
        y2, s2 = ssm._mlstm_chunkwise(CFG_X, p, x, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=2e-4)
        for k in ("C", "n", "m"):
            np.testing.assert_allclose(
                np.asarray(s1[k]), np.asarray(s2[k]), atol=2e-4, rtol=2e-3
            )


def test_mlstm_recurrent_matches_parallel():
    """Step-by-step decode over the same tokens == parallel form outputs."""
    p = ssm.mlstm_init(KEY, CFG_X)
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, CFG_X.d_model))
    y_par, _ = ssm._mlstm_parallel(CFG_X, p, x)
    cache = ssm.mlstm_cache_init(CFG_X, B, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = ssm.mlstm_decode(CFG_X, p, x[:, t : t + 1], cache)
        outs.append(y)
    y_rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec), atol=2e-4, rtol=2e-3)


def test_mlstm_prefill_state_equals_decode_state():
    """Final (C, n, m) from the parallel form == state after stepwise decode."""
    p = ssm.mlstm_init(KEY, CFG_X)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, CFG_X.d_model))
    _, s_par = ssm._mlstm_parallel(CFG_X, p, x)
    cache = ssm.mlstm_cache_init(CFG_X, B, jnp.float32)
    for t in range(S):
        _, cache = ssm.mlstm_decode(CFG_X, p, x[:, t : t + 1], cache)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(
            np.asarray(s_par[k]), np.asarray(cache[k]), atol=2e-4, rtol=2e-3
        )


def test_mamba_decode_matches_scan():
    p = ssm.mamba_init(KEY, CFG_J)
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, CFG_J.d_model))
    y_full, final = ssm.mamba_apply(CFG_J, p, x)
    cache = ssm.mamba_cache_init(CFG_J, B, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = ssm.mamba_decode(CFG_J, p, x[:, t : t + 1], cache)
        outs.append(y)
    y_rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_rec), atol=3e-4, rtol=3e-3)
    np.testing.assert_allclose(
        np.asarray(final["h"]), np.asarray(cache["h"]), atol=3e-4, rtol=3e-3
    )


@given(chunk=st.sampled_from([4, 8, 16]), s=st.sampled_from([16, 32, 64]))
@settings(max_examples=8)
def test_mamba_chunked_scan_chunk_invariance(chunk, s):
    """The chunked selective scan must be invariant to chunk size."""
    rng = np.random.default_rng(0)
    B, di, ds = 2, 8, 4
    a = jnp.asarray(rng.uniform(0.7, 0.999, (B, s, di, ds)), jnp.float32)
    bx = jnp.asarray(rng.normal(size=(B, s, di, ds)) * 0.1, jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, s, ds)), jnp.float32)
    h0 = jnp.zeros((B, di, ds), jnp.float32)
    y1, h1 = ssm._selective_scan_chunked(a, bx, C, h0, chunk=s)  # single chunk
    y2, h2 = ssm._selective_scan_chunked(a, bx, C, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5, rtol=1e-4)


def test_slstm_decode_matches_scan():
    p = ssm.slstm_init(KEY, CFG_X)
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, CFG_X.d_model))
    y_full, final = ssm.slstm_apply(CFG_X, p, x)
    cache = ssm.slstm_cache_init(CFG_X, B, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = ssm.slstm_decode(CFG_X, p, x[:, t : t + 1], cache)
        outs.append(y)
    y_rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_rec), atol=2e-4, rtol=2e-3)
    for k in ("c", "n", "h", "m"):
        np.testing.assert_allclose(
            np.asarray(final[k]), np.asarray(cache[k]), atol=2e-4, rtol=2e-3
        )


def test_gate_stability_extreme_inputs():
    """Log-space gates: huge inputs must not overflow (500k-decode safety)."""
    p = ssm.mlstm_init(KEY, CFG_X)
    x = 50.0 * jax.random.normal(jax.random.PRNGKey(6), (1, 64, CFG_X.d_model))
    y, s = ssm._mlstm_parallel(CFG_X, p, x)
    assert np.isfinite(np.asarray(y)).all()
    y2, s2 = ssm._mlstm_chunkwise(CFG_X, p, x, chunk=16)
    assert np.isfinite(np.asarray(y2)).all()
