"""Toggle-policy layer tests (the PR's behavior-preservation contract).

The load-bearing property: ``ReactivePolicy`` through the shared
``policy_scan`` kernel reproduces the pre-refactor planners BIT-FOR-BIT —
``run_togglecci`` on random tier tables/delays/demand traces, and the
``plan_fleet`` / ``plan_topology`` decision sequences against their float64
references. Plus: hysteresis degenerates to reactive at hold=1, the
forecast gate's early-fire/suppress mechanics, forecaster training and
causality, spec policy threading, and the pair-move routing refinement.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.costmodel import HourlyCosts, hourly_cost_series
from repro.core.pricing import CostParams, TieredRate, flat_rate
from repro.core.togglecci import OFF, ToggleParams, run_togglecci
from repro.fleet.plan import (
    build_fleet_scenario,
    build_topology_report,
    build_topology_scenario,
    forecast_gated_policy,
    hysteresis_policy,
    make_policy,
    optimize_routing,
    plan_fleet,
    plan_fleet_reference,
    plan_topology,
    plan_topology_reference,
    reactive_policy,
    refine_routing,
)
from repro.fleet.policy import policy_scan
from repro.fleet.spec import FleetSpec, LinkSpec, fleet_from_params
from repro.fleet.topology import PairSpec, PortSpec, TopologySpec

HORIZON = 1200


def _random_params(rng: np.random.Generator) -> CostParams:
    """Random pricing + FSM operating point incl. a random ragged tier table."""
    k = int(rng.integers(1, 4))
    bounds = np.sort(rng.uniform(50, 5000, size=k))
    rates = np.sort(rng.uniform(0.02, 0.2, size=k))[::-1]  # decreasing marginal
    tier = TieredRate(tuple(bounds[:-1]) + (np.inf,), tuple(rates))
    return CostParams(
        L_cci=float(rng.uniform(0.5, 8.0)),
        V_cci=float(rng.uniform(0.05, 0.5)),
        c_cci=float(rng.uniform(0.005, 0.05)),
        L_vpn=float(rng.uniform(0.05, 0.5)),
        vpn_tier=tier,
        D=int(rng.integers(0, 40)),
        T_cci=int(rng.integers(1, 80)),
        h=int(rng.integers(1, 80)),
        theta1=float(rng.uniform(0.8, 1.0)),
        theta2=float(rng.uniform(1.0, 1.25)),
    )


def _random_demand(rng: np.random.Generator, T: int) -> np.ndarray:
    """Regime-switching demand so the FSM actually transitions."""
    base = rng.uniform(0, 400)
    d = np.full(T, base)
    for _ in range(int(rng.integers(1, 6))):
        a, b = np.sort(rng.integers(0, T, size=2))
        d[a:b] = rng.uniform(0, 4000)
    return d * rng.uniform(0.8, 1.2, size=T)


# ---------------------------------------------------------------------------
# ReactivePolicy == the paper's FSM, bit-for-bit (the tentpole property)
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000))
@settings(max_examples=12)
def test_reactive_policy_scan_matches_run_togglecci(seed):
    """Random tier tables, delays, thresholds and demand traces: the shared
    policy_scan kernel with a ReactivePolicy must reproduce the pure-Python
    reference FSM bit-for-bit, in both renewal semantics."""
    rng = np.random.default_rng(seed)
    params = _random_params(rng)
    d = _random_demand(rng, int(rng.integers(50, 700)))
    costs = hourly_cost_series(params, d)
    tp = ToggleParams.from_cost_params(params)
    for renew in (False, True):
        ref = run_togglecci(params, d, costs=costs, renew_in_chunks=renew)
        out = policy_scan(
            reactive_policy(tp, renew_in_chunks=renew),
            jnp.asarray(costs.vpn),
            jnp.asarray(costs.cci),
        )
        np.testing.assert_array_equal(np.asarray(out["x"]), ref.x)
        np.testing.assert_array_equal(np.asarray(out["state"]), ref.state)


@given(seed=st.integers(0, 1000))
@settings(max_examples=2)
def test_reactive_policy_reproduces_plan_fleet(seed):
    """plan_fleet with an EXPLICIT ReactivePolicy == the per-link float64
    reference == plan_fleet with the default policy (all bit-for-bit)."""
    sc = build_fleet_scenario(8, horizon=HORIZON, seed=seed)
    with enable_x64():
        arrays = sc.fleet.stack(jnp.float64)
        pol = reactive_policy(arrays.toggle, renew_in_chunks=False)
    explicit = plan_fleet(arrays, sc.demand, policy=pol,
                          hours_per_month=sc.fleet.hours_per_month)
    default = plan_fleet(sc.fleet, sc.demand)
    ref = plan_fleet_reference(sc.fleet, sc.demand)
    for plan in (explicit, default):
        np.testing.assert_array_equal(np.asarray(plan["x"]), ref["x"])
        np.testing.assert_array_equal(np.asarray(plan["state"]), ref["state"])


@given(seed=st.integers(0, 1000))
@settings(max_examples=2)
def test_reactive_policy_reproduces_plan_topology(seed):
    """plan_topology decision sequences through the policy layer stay
    bit-exact vs the per-port float64 reference FSM on the engine's own
    port cost series (the plan_topology_reference policy contract)."""
    sc = build_topology_scenario(10, n_facilities=3, horizon=HORIZON, seed=seed)
    routing = optimize_routing(sc.topo, sc.demand)
    with enable_x64():
        arrays = sc.topo.stack(routing, jnp.float64)
        pol = reactive_policy(arrays.toggle)
    plan = plan_topology(arrays, sc.demand, policy=pol,
                         hours_per_month=sc.topo.hours_per_month)
    series = {
        "vpn": np.asarray(plan["vpn_hourly"]),
        "cci": np.asarray(plan["cci_hourly"]),
    }
    ref = plan_topology_reference(sc.topo, sc.demand, routing, port_costs=series)
    np.testing.assert_array_equal(np.asarray(plan["x"]), ref["x"])
    np.testing.assert_array_equal(np.asarray(plan["state"]), ref["state"])
    # And the default-policy path is the same compiled program + operands.
    default = plan_topology(sc.topo, sc.demand, routing=routing)
    np.testing.assert_array_equal(np.asarray(default["x"]), ref["x"])


# ---------------------------------------------------------------------------
# HysteresisPolicy
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000))
@settings(max_examples=6)
def test_hysteresis_hold_one_equals_reactive(seed):
    rng = np.random.default_rng(seed)
    params = _random_params(rng)
    d = _random_demand(rng, 400)
    costs = hourly_cost_series(params, d)
    tp = ToggleParams.from_cost_params(params)
    vpn, cci = jnp.asarray(costs.vpn), jnp.asarray(costs.cci)
    ra = policy_scan(reactive_policy(tp), vpn, cci)
    hy = policy_scan(hysteresis_policy(tp, up_hold=1, down_hold=1), vpn, cci)
    np.testing.assert_array_equal(np.asarray(hy["x"]), np.asarray(ra["x"]))
    np.testing.assert_array_equal(np.asarray(hy["state"]), np.asarray(ra["state"]))


def test_hysteresis_debounces_threshold_chatter():
    """Demand oscillating across breakeven: long holds must cut switches."""
    params = CostParams(2.0, 0.1, 0.02, 0.1, flat_rate(0.1), D=2, T_cci=6, h=4)
    rng = np.random.default_rng(1)
    d = np.where(rng.random(2000) < 0.5, 250.0, 20.0)
    costs = hourly_cost_series(params, d)
    tp = ToggleParams.from_cost_params(params)
    vpn, cci = jnp.asarray(costs.vpn), jnp.asarray(costs.cci)
    switches = lambda out: int(
        np.abs(np.diff(np.asarray(out["x"]))).sum()
    )
    ra = policy_scan(reactive_policy(tp), vpn, cci)
    hy = policy_scan(hysteresis_policy(tp, up_hold=12, down_hold=12), vpn, cci)
    assert switches(hy) < switches(ra)


# ---------------------------------------------------------------------------
# ForecastGatedPolicy mechanics (constructed, deterministic predictions)
# ---------------------------------------------------------------------------


def _step_case():
    """Low demand, then a sustained high regime at t0 — the shape whose
    provisioning delay the forecast gate is built to pre-empt."""
    params = CostParams(2.0, 0.1, 0.02, 0.1, flat_rate(0.1),
                        D=48, T_cci=96, h=96)
    T, t0 = 1500, 600
    d = np.full(T, 10.0)
    d[t0:] = 2000.0
    return params, d


def _true_forward_mean(d: np.ndarray, W: int) -> np.ndarray:
    cs = np.concatenate([[0.0], np.cumsum(d)])
    T = d.shape[0]
    hi = np.minimum(np.arange(T) + W, T)
    return (cs[hi] - cs[np.arange(T)]) / np.maximum(hi - np.arange(T), 1)


def test_forecast_policy_fires_early_on_sustained_regime_shift():
    """With a perfect demand forecast the gated policy must request BEFORE
    the reactive trailing window can react, and end up strictly cheaper."""
    params, d = _step_case()
    costs = hourly_cost_series(params, d)
    tp = ToggleParams.from_cost_params(params)
    W = params.D + params.T_cci
    pred = _true_forward_mean(d, W)
    vpn, cci = jnp.asarray(costs.vpn), jnp.asarray(costs.cci)
    ra = policy_scan(reactive_policy(tp), vpn, cci)
    fo = policy_scan(
        forecast_gated_policy(tp, pred, margin=0.05),
        vpn, cci, demand=jnp.asarray(d),
    )
    first_req = lambda out: int(np.argmax(np.asarray(out["state"]) != OFF))
    assert first_req(fo) < first_req(ra), "forecast must fire earlier"
    assert float(fo["total_cost"]) < float(ra["total_cost"])


def test_forecast_policy_suppresses_transient_spike():
    """A short demand spike trips the reactive request (whole provisioning
    delay + commitment bought for a spike that is shorter than the delay
    itself) — the forecast gate, whose D+T_cci forward-window mean stays
    below the lease breakeven, must suppress it."""
    params = CostParams(2.0, 0.1, 0.02, 0.1, flat_rate(0.1),
                        D=24, T_cci=200, h=12)
    T = 1200
    d = np.full(T, 5.0)
    d[300:315] = 300.0  # 15 h spike < D; window mean stays ~breakeven
    costs = hourly_cost_series(params, d)
    tp = ToggleParams.from_cost_params(params)
    pred = _true_forward_mean(d, params.D + params.T_cci)
    vpn, cci = jnp.asarray(costs.vpn), jnp.asarray(costs.cci)
    ra = policy_scan(reactive_policy(tp), vpn, cci)
    fo = policy_scan(
        forecast_gated_policy(tp, pred, margin=0.05),
        vpn, cci, demand=jnp.asarray(d),
    )
    assert np.asarray(ra["x"]).sum() > 0, "reactive takes the bait"
    assert np.asarray(fo["x"]).sum() == 0, "forecast suppresses the spike"
    assert float(fo["total_cost"]) < float(ra["total_cost"])


def test_forecast_policy_through_plan_fleet():
    """End-to-end: a ForecastGatedPolicy as the vmapped plan_fleet operand
    (per-link pred_demand rows), beating reactive on the step trace."""
    params, d = _step_case()
    fleet = fleet_from_params([params, params])
    demand = np.stack([d, d])
    with enable_x64():
        arrays = fleet.stack(jnp.float64)
        pred = np.stack([
            _true_forward_mean(row, params.D + params.T_cci) for row in demand
        ])
        pol = forecast_gated_policy(arrays.toggle, pred, margin=0.05)
    fplan = plan_fleet(arrays, demand, policy=pol,
                       hours_per_month=fleet.hours_per_month)
    rplan = plan_fleet(fleet, demand)
    assert np.all(
        np.asarray(fplan["toggle_cost"]) < np.asarray(rplan["toggle_cost"])
    )


# ---------------------------------------------------------------------------
# Forecaster training (models/ssm.py demand head)
# ---------------------------------------------------------------------------


def test_forecaster_training_improves_on_persistence():
    from repro.models.ssm import (
        demand_forecaster_apply,
        demand_forecaster_init,
        train_demand_forecaster,
    )

    rng = np.random.default_rng(0)
    t = np.arange(1200)
    series = np.stack([
        50 * (1 + 0.5 * np.sin(2 * np.pi * t / 168)) + rng.normal(0, 2, t.size),
        30 * (1 + t / 1200) + rng.normal(0, 2, t.size),
    ]).clip(min=0.0)
    W = 100
    params, scale = train_demand_forecaster(series, W, steps=200, seed=0)

    u = jnp.log1p(jnp.asarray(series / scale[:, None], jnp.float32))
    cs = np.concatenate([np.zeros((2, 1)), np.cumsum(series / scale[:, None], axis=1)], axis=1)
    T = series.shape[1]
    target = np.log1p((cs[:, W + 1:] - cs[:, 1:T - W + 1]) / W)  # t <= T-W-1
    valid = slice(0, T - W)

    def mse(p):
        y = np.asarray(demand_forecaster_apply(p, u), np.float64)
        return float(np.mean((y[:, valid] - target) ** 2))

    init = demand_forecaster_init(None)
    assert mse(params) < mse(init) * 0.9, (
        "training must beat the persistence init on seasonal/trend series"
    )


def test_forecast_port_demand_is_causal():
    """Perturbing live demand after hour k must not change predictions at
    hours <= k (the forecaster never sees the future)."""
    from repro.fleet.policy import forecast_port_demand

    rng = np.random.default_rng(3)
    hist = rng.uniform(10, 100, size=(3, 300))
    live = rng.uniform(10, 100, size=(3, 200))
    k = 120
    live2 = live.copy()
    live2[:, k:] *= 7.0
    a = forecast_port_demand(hist, live, 50, steps=10, seed=0)
    b = forecast_port_demand(hist, live2, 50, steps=10, seed=0)
    np.testing.assert_array_equal(a[:, : k + 1], b[:, : k + 1])
    assert a.shape == live.shape and (a >= 0).all()


# ---------------------------------------------------------------------------
# Spec threading + factory validation
# ---------------------------------------------------------------------------


def test_spec_policy_threading_and_validation():
    p = CostParams(2.0, 0.1, 0.02, 0.1, flat_rate(0.1), D=3, T_cci=6, h=6)
    link = LinkSpec("l0", p)
    d = np.full((1, 300), 150.0)
    hy = plan_fleet(FleetSpec((link,), policy="hysteresis"), d)
    ra = plan_fleet(FleetSpec((link,)), d)
    assert hy["x"].shape == ra["x"].shape  # same engine, different policy
    with pytest.raises(AssertionError, match="unknown toggle policy"):
        FleetSpec((link,), policy="psychic")
    with pytest.raises(AssertionError, match="unknown toggle policy"):
        TopologySpec(
            ports=(PortSpec("p", "f", "aws", 4.0, 0.1, 0.02),),
            pairs=(PairSpec("a", "gcp", "aws", 0.1, flat_rate(0.1),
                            candidates=(0,)),),
            policy="psychic",
        )
    with pytest.raises(ValueError, match="forecast"):
        make_policy("forecast", ToggleParams.from_cost_params(p))
    with pytest.raises(ValueError, match="unknown"):
        make_policy("psychic", ToggleParams.from_cost_params(p))


# ---------------------------------------------------------------------------
# Routing refinement (pair-move local search)
# ---------------------------------------------------------------------------


def _two_port_topo(c0=0.02, c1=0.02, L0=4.0, L1=4.0):
    mk = lambda n, L, c: PortSpec(
        name=n, facility=f"f-{n}", cloud="aws", L_cci=L, V_cci=0.1, c_cci=c,
        D=6, T_cci=12, h=12,
    )
    pairs = tuple(
        PairSpec(f"pr{i}", "gcp", "aws", 0.105, flat_rate(0.1), candidates=(0, 1))
        for i in range(2)
    )
    return TopologySpec(ports=(mk("p0", L0, c0), mk("p1", L1, c1)), pairs=pairs)


def test_refine_routing_recovers_from_bad_routing():
    """Both pairs parked on the expensive port: the local search must move
    them to the cheap one, replanning only the affected ports, and the
    claimed cost drop must match a full replan."""
    topo = _two_port_topo(c0=0.01, c1=0.2, L0=2.0, L1=20.0)
    rng = np.random.default_rng(0)
    d = rng.uniform(150, 250, size=(2, 600))
    bad = topo.plan([1, 1])
    refined, info = refine_routing(topo, d, bad, max_moves=4)
    assert list(refined.primary) == [0, 0], (
        "both pairs must migrate to the cheap port"
    )
    assert info["cost_after"] < info["cost_before"]
    assert all(m[3] > 0 for m in info["moves"])
    replan = plan_topology(topo, d, routing=refined)
    assert float(np.sum(np.asarray(replan["toggle_cost"]))) == pytest.approx(
        info["cost_after"], rel=1e-9
    )


def test_refine_routing_never_worsens_greedy():
    sc = build_topology_scenario(12, n_facilities=3, horizon=800, seed=4)
    routing = optimize_routing(sc.topo, sc.demand)
    plan = plan_topology(sc.topo, sc.demand, routing=routing)
    refined, info = refine_routing(sc.topo, sc.demand, routing, max_moves=3)
    assert info["cost_after"] <= info["cost_before"] + 1e-6
    # cost_before is the realized plan cost of the input routing.
    assert info["cost_before"] == pytest.approx(
        float(np.sum(np.asarray(plan["toggle_cost"]))), rel=1e-9
    )
    sc.topo.validate_routing(refined)  # moves only within candidate sets


def test_report_forecast_and_refinement_columns():
    sc = build_topology_scenario(
        8, n_facilities=2, horizon=800, history_hours=400,
        families=("bursty",), seed=6,
    )
    routing = optimize_routing(sc.topo, sc.demand)
    plan = plan_topology(sc.topo, sc.demand, routing=routing)
    from repro.fleet.plan import forecast_topology_policy

    with enable_x64():
        arrays = sc.topo.stack(routing, jnp.float64)
    fpol = forecast_topology_policy(arrays, sc.demand, sc.history, steps=60)
    fplan = plan_topology(arrays, sc.demand, policy=fpol,
                          hours_per_month=sc.topo.hours_per_month)
    rep = build_topology_report(
        sc, plan, routing,
        include_oracle=True, forecast_plan=fplan,
        refine=True, refine_max_moves=2,
    )
    t = rep.totals
    assert "forecast" in t and "forecast_gain" in t
    assert "refined_cost" in t and "routing_improvement" in t
    assert t["refined_cost"] <= t["togglecci"] + 1e-6
    assert t["oracle"] <= t["forecast"] * (1 + 1e-9)
    # Per-port column threading.
    assert all(p.forecast_cost is not None for p in rep.ports)
    text = rep.render_text()
    assert "forecast-gated" in text and "refined routing" in text

    # refine must also work when the SPEC's default policy kind is one the
    # engine cannot auto-resolve ("forecast") — the refinement replan is
    # explicitly reactive, compared against the reactive base cost.
    sc2 = dataclasses.replace(
        sc, topo=dataclasses.replace(sc.topo, policy="forecast")
    )
    rep2 = build_topology_report(
        sc2, fplan, routing, include_dedicated_baseline=False,
        refine=True, refine_max_moves=1,
    )
    t2 = rep2.totals
    assert t2["refined_cost"] <= rep2.refine_base_cost + 1e-6
