"""Fleet subsystem tests: SoA stacking, batched-vs-sequential equivalence,
capacity ceilings, tier-table padding, and the report layer."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core.costmodel import tiered_marginal_cost_tables
from repro.core.pricing import CostParams, TieredRate, flat_rate, make_scenario
from repro.core.togglecci import run_togglecci
from repro.fleet.plan import (
    FleetScenario,
    FleetSpec,
    LinkSpec,
    build_fleet_scenario,
    build_report,
    fleet_from_params,
    link_capacity_gb_hr,
    plan_fleet,
    plan_fleet_reference,
    toggle_events,
)
from repro.fleet.spec import PAD_BOUND

HORIZON = 1600


# ---------------------------------------------------------------------------
# Spec stacking
# ---------------------------------------------------------------------------


def test_stack_shapes_and_tier_padding():
    p_deep = make_scenario("aws", "gcp")            # 4-tier AWS egress
    p_flat = CostParams(1.0, 0.1, 0.02, 0.1, flat_rate(0.1))  # 1-tier
    fleet = fleet_from_params([p_deep, p_flat])
    arr = fleet.stack()
    assert arr.n_links == 2
    K = len(p_deep.vpn_tier.bounds_gb)
    assert arr.tier_bounds.shape == arr.tier_rates.shape == (2, K)
    # Padded rows: bound = PAD_BOUND, rate = 0 -> zero-width, zero-cost.
    np.testing.assert_allclose(np.asarray(arr.tier_bounds)[1, 1:], PAD_BOUND, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(arr.tier_rates)[1, 1:], 0.0)
    assert arr.toggle.D.shape == (2,)


def test_stack_rejects_mixed_billing_calendars():
    a = make_scenario("gcp", "aws")
    b = make_scenario("gcp", "aws", hours_per_month=720)
    with pytest.raises(AssertionError):
        fleet_from_params([a, b])


def test_padded_tier_tables_match_scalar_marginal_cost():
    tiers = [
        TieredRate((100.0, 1000.0, np.inf), (0.12, 0.08, 0.05)),
        flat_rate(0.1),
    ]
    params = [
        CostParams(1.0, 0.1, 0.02, 0.1, t) for t in tiers
    ]
    arr = fleet_from_params(params).stack()
    rng = np.random.default_rng(0)
    start = rng.uniform(0, 2000, size=(2, 64))
    added = rng.uniform(0, 500, size=(2, 64))
    got = np.asarray(
        tiered_marginal_cost_tables(
            jnp.asarray(start, jnp.float32),
            jnp.asarray(added, jnp.float32),
            arr.tier_bounds,
            arr.tier_rates,
        )
    )
    for i, t in enumerate(tiers):
        want = [t.marginal_cost(s, a) for s, a in zip(start[i], added[i])]
        np.testing.assert_allclose(got[i], want, rtol=1e-4)


# ---------------------------------------------------------------------------
# Batched engine == per-link Python reference (the tentpole property)
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 1000))
@settings(max_examples=3)
def test_batched_matches_sequential_all_families(seed):
    """16 random heterogeneous links x 4 trace families, both renewal
    semantics: the one-jit-call vmapped plan must reproduce the per-link
    float64 Python reference BIT-FOR-BIT on x and state."""
    sc = build_fleet_scenario(16, horizon=HORIZON, seed=seed)
    assert set(sc.summary()) == {"constant", "bursty", "mirage", "puffer"}
    for renew in (False, True):
        plan = plan_fleet(sc.fleet, sc.demand, renew_in_chunks=renew)
        ref = plan_fleet_reference(sc.fleet, sc.demand, renew_in_chunks=renew)
        np.testing.assert_array_equal(np.asarray(plan["x"]), ref["x"])
        np.testing.assert_array_equal(np.asarray(plan["state"]), ref["state"])
        np.testing.assert_allclose(
            np.asarray(plan["toggle_cost"]), ref["toggle_cost"], rtol=1e-9
        )


def test_engine_pallas_tier_path_matches_xla():
    """use_pallas=True must work off-TPU (interpret mode, padded blocks) and
    agree with the XLA tier path to f32 resolution."""
    sc = build_fleet_scenario(4, horizon=700, seed=2)  # 700 % 512 != 0: pads
    ref = plan_fleet(sc.fleet, sc.demand)
    pal = plan_fleet(sc.fleet, sc.demand, use_pallas=True)
    # f32 month-cumulative volumes (~1e5-1e6 GB) resolve tier boundaries to
    # ~0.06 GB, so per-hour costs carry cents-level noise vs the f64 path
    # (same convention as test_kernels' tiered_cost checks): loose absolute
    # tolerance per hour, tight relative on the totals.
    np.testing.assert_allclose(
        np.asarray(pal["vpn_hourly"]), np.asarray(ref["vpn_hourly"]), atol=2e-2
    )
    np.testing.assert_allclose(
        np.asarray(pal["toggle_cost"]), np.asarray(ref["toggle_cost"]), rtol=1e-3
    )


def test_static_cci_pays_provisioning_delay():
    p = CostParams(1.0, 0.1, 0.02, 0.5, flat_rate(0.5), D=10, T_cci=5, h=6)
    fleet = fleet_from_params([p])
    d = np.full((1, 200), 100.0)
    plan = plan_fleet(fleet, d)
    vpn = np.asarray(plan["vpn_hourly"])[0]
    cci = np.asarray(plan["cci_hourly"])[0]
    want = vpn[:10].sum() + cci[10:].sum()
    assert float(plan["static_cci"][0]) == pytest.approx(want, rel=1e-12)


def test_capacity_ceiling_clips_demand():
    p = make_scenario("gcp", "aws")
    cap = 500.0
    fleet = FleetSpec((LinkSpec("l0", p, capacity_gb_hr=cap),))
    d = np.full((1, 400), 10_000.0)   # far above the ceiling
    plan = plan_fleet(fleet, d)
    np.testing.assert_array_equal(np.asarray(plan["demand"])[0], cap)
    # And the reference clips identically.
    ref = plan_fleet_reference(fleet, d)
    np.testing.assert_array_equal(np.asarray(plan["x"]), ref["x"])


def test_heterogeneous_toggle_params_differ_across_links():
    """Two links, identical demand/prices but different thresholds, must
    produce different plans inside ONE batched call (per-link operands)."""
    base = dict(L_cci=2.0, V_cci=0.1, c_cci=0.02, L_vpn=0.1, vpn_tier=flat_rate(0.1))
    eager = CostParams(**base, D=5, T_cci=10, h=10, theta1=0.99, theta2=1.01)
    never = CostParams(**base, D=5, T_cci=10, h=10, theta1=0.01, theta2=100.0)
    fleet = fleet_from_params([eager, never])
    rng = np.random.default_rng(0)
    d = np.tile(rng.uniform(50, 150, size=600), (2, 1))
    plan = plan_fleet(fleet, d)
    x = np.asarray(plan["x"])
    assert x[0].sum() > 0, "aggressive thresholds should activate CCI"
    assert x[1].sum() == 0, "impossible thresholds should never activate"


# ---------------------------------------------------------------------------
# Scenario builder
# ---------------------------------------------------------------------------


def test_scenario_shapes_and_capacity():
    sc = build_fleet_scenario(8, horizon=HORIZON, seed=1)
    assert isinstance(sc, FleetScenario)
    assert sc.demand.shape == (8, HORIZON)
    assert (sc.demand >= 0).all()
    for link in sc.fleet.links:
        assert link.capacity_gb_hr <= link_capacity_gb_hr(10) + 1e-9


def test_link_capacity_is_linksim_calibrated():
    from repro.traffic import linksim

    # Small VLANs bottleneck on the elastic VLAN; big ones on the hard CCI cap.
    assert link_capacity_gb_hr(1) == pytest.approx(1 * 1.7 * 450.0)
    assert link_capacity_gb_hr(10) == pytest.approx(
        linksim.CCI_NOMINAL_GBPS * (1 - linksim.CCI_OVERHEAD) * 450.0
    )


# ---------------------------------------------------------------------------
# Report layer
# ---------------------------------------------------------------------------


def test_toggle_events_match_reference_lists():
    sc = build_fleet_scenario(6, horizon=HORIZON, seed=7)
    plan = plan_fleet(sc.fleet, sc.demand)
    state = np.asarray(plan["state"])
    for i, link in enumerate(sc.fleet.links):
        d = np.minimum(sc.demand[i], link.capacity_gb_hr)
        ref = run_togglecci(link.params, d)
        req, rel = toggle_events(state[i])
        assert list(req) == ref.requests
        assert list(rel) == ref.releases


def test_report_aggregates_and_oracle_bound():
    sc = build_fleet_scenario(6, horizon=HORIZON, seed=11)
    plan = plan_fleet(sc.fleet, sc.demand)
    rep = build_report(sc, plan, include_oracle=True)
    assert len(rep.links) == 6
    t = rep.totals
    assert t["togglecci"] == pytest.approx(
        sum(l.toggle_cost for l in rep.links)
    )
    # OPT lower-bounds every policy, per link and in aggregate.
    for l in rep.links:
        assert l.oracle_cost is not None
        assert l.oracle_cost <= l.toggle_cost * (1 + 1e-9)
        assert l.oracle_cost <= l.best_static * (1 + 1e-9)
    assert "oracle" in t
    text = rep.render_text()
    assert "fleet total" in text and rep.links[0].name in text
