"""Topology subsystem tests: spec stacking/validation, the identity-routing
degeneration property (bit-for-bit vs the PR-1 per-link planner), the
multi-pair engine vs its per-port float64 reference, routing optimization,
port-capacity semantics, and the topology report."""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.pricing import flat_rate
from repro.core.togglecci import window_sums
from repro.fleet.plan import (
    PairSpec,
    PortSpec,
    TopologyScenario,
    TopologySpec,
    build_fleet_scenario,
    build_topology_report,
    build_topology_scenario,
    dedicated_fleet,
    identity_topology,
    optimize_routing,
    plan_fleet,
    plan_topology,
    plan_topology_reference,
    port_capacity_gb_hr,
    routing_matrix,
    topology_oracle,
    vlan_access_gb_hr,
)

HORIZON = 1500


def _one_port(name="p0", facility="fac00", **kw) -> PortSpec:
    base = dict(
        cloud="aws", L_cci=4.55, V_cci=0.1, c_cci=0.02,
        D=6, T_cci=12, h=12, theta1=0.9, theta2=1.1,
    )
    base.update(kw)
    return PortSpec(name=name, facility=facility, **base)


def _one_pair(name, candidates, **kw) -> PairSpec:
    base = dict(
        src="gcp", dst="aws", L_vpn=0.105, vpn_tier=flat_rate(0.1),
    )
    base.update(kw)
    return PairSpec(name=name, candidates=tuple(candidates), **base)


# ---------------------------------------------------------------------------
# Spec stacking and validation
# ---------------------------------------------------------------------------


def test_stack_shapes_and_routing_matrix():
    topo = TopologySpec(
        ports=(_one_port("p0"), _one_port("p1", facility="fac01")),
        pairs=(
            _one_pair("a", (0, 1)),
            _one_pair("b", (1,)),
            _one_pair("c", (0,)),
        ),
    )
    arr = topo.stack(topo.plan([0, 1, 0]))
    assert arr.n_ports == 2 and arr.n_pairs == 3
    op = arr.routing
    assert op.leg_pair.shape == op.leg_port.shape == (3,)
    np.testing.assert_array_equal(np.asarray(op.leg_pair), [0, 1, 2])
    np.testing.assert_array_equal(np.asarray(op.leg_port), [0, 1, 0])
    np.testing.assert_array_equal(np.asarray(op.vpn_w), 1.0)
    np.testing.assert_array_equal(np.asarray(op.attach_w), 1.0)
    np.testing.assert_array_equal(np.asarray(op.primary), [0, 1, 0])
    assert arr.toggle.D.shape == (2,)
    assert arr.tier_bounds.shape == arr.tier_rates.shape == (3, 1)
    # candidate matrix mirrors the per-pair candidate tuples
    np.testing.assert_array_equal(
        topo.candidate_matrix(),
        [[True, True], [False, True], [True, False]],
    )


def test_routing_must_respect_candidates():
    topo = TopologySpec(
        ports=(_one_port("p0"), _one_port("p1")),
        pairs=(_one_pair("a", (1,)),),
    )
    with pytest.raises(AssertionError, match="non-candidate"):
        topo.stack(topo.plan([0]))
    with pytest.raises(AssertionError):
        topo.stack(topo.plan([0, 1]))  # wrong shape


def test_pair_requires_candidates_and_indices_in_range():
    with pytest.raises(AssertionError):
        _one_pair("a", ())
    with pytest.raises(AssertionError):
        TopologySpec(ports=(_one_port(),), pairs=(_one_pair("a", (3,)),))


def test_routing_matrix_is_padded_one_hot():
    R = np.asarray(routing_matrix(np.array([2, 0, 2]), 4))
    assert R.shape == (4, 3)
    np.testing.assert_array_equal(R.sum(axis=0), 1.0)  # one port per pair
    np.testing.assert_array_equal(R[1], 0.0)           # idle port row padded
    np.testing.assert_array_equal(R[3], 0.0)


# ---------------------------------------------------------------------------
# The satellite property: identity routing degenerates to PR-1 plan_fleet
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 1000))
@settings(max_examples=3)
def test_identity_routing_reproduces_plan_fleet_bit_for_bit(seed):
    """A routing matrix degenerating to the identity (one private port per
    link, unbounded port capacity) must reproduce the PR-1 per-link planner
    BIT-FOR-BIT: decisions, states, and total costs."""
    sc = build_fleet_scenario(12, horizon=HORIZON, seed=seed)
    topo, routing = identity_topology(sc.fleet)
    for renew in (False, True):
        pf = plan_fleet(sc.fleet, sc.demand, renew_in_chunks=renew)
        pt = plan_topology(topo, sc.demand, routing=routing, renew_in_chunks=renew)
        np.testing.assert_array_equal(np.asarray(pt["x"]), np.asarray(pf["x"]))
        np.testing.assert_array_equal(
            np.asarray(pt["state"]), np.asarray(pf["state"])
        )
        # Costs too: the aggregation stage adds only exact zeros.
        np.testing.assert_array_equal(
            np.asarray(pt["toggle_cost"]), np.asarray(pf["toggle_cost"])
        )
        np.testing.assert_array_equal(
            np.asarray(pt["vpn_hourly"]), np.asarray(pf["vpn_hourly"])
        )
        np.testing.assert_array_equal(
            np.asarray(pt["static_cci"]), np.asarray(pf["static_cci"])
        )


# ---------------------------------------------------------------------------
# Multi-pair engine == per-port float64 Python reference
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 1000))
@settings(max_examples=2)
def test_topology_engine_matches_reference_all_families(seed):
    """Two-part exactness contract (see plan_topology_reference): the FSM is
    bit-for-bit on identical port cost series, and the engine's matmul
    aggregation reproduces the independent numpy aggregation to f64 ulp
    (comparing decisions ACROSS the two aggregations directly would be
    flaky whenever a window sum lands within an ulp of a θ threshold)."""
    from repro.fleet.plan import topology_port_costs_reference

    sc = build_topology_scenario(12, n_facilities=3, horizon=HORIZON, seed=seed)
    assert set(sc.summary()) == {"constant", "bursty", "mirage", "puffer"}
    routing = optimize_routing(sc.topo, sc.demand)
    ind = topology_port_costs_reference(sc.topo, sc.demand, routing)
    for renew in (False, True):
        plan = plan_topology(sc.topo, sc.demand, routing=routing, renew_in_chunks=renew)
        series = {
            "vpn": np.asarray(plan["vpn_hourly"]),
            "cci": np.asarray(plan["cci_hourly"]),
        }
        ref = plan_topology_reference(
            sc.topo, sc.demand, routing,
            renew_in_chunks=renew, port_costs=series,
        )
        np.testing.assert_array_equal(np.asarray(plan["x"]), ref["x"])
        np.testing.assert_array_equal(np.asarray(plan["state"]), ref["state"])
        np.testing.assert_allclose(
            np.asarray(plan["toggle_cost"]), ref["toggle_cost"], rtol=1e-9
        )
        np.testing.assert_allclose(series["vpn"], ind["vpn"], rtol=1e-12, atol=1e-9)
        np.testing.assert_allclose(series["cci"], ind["cci"], rtol=1e-12, atol=1e-9)


def test_plan_topology_default_routing_co_optimizes():
    sc = build_topology_scenario(8, n_facilities=2, horizon=600, seed=5)
    plan = plan_topology(sc.topo, sc.demand)  # routing=None -> optimize_routing
    want = optimize_routing(sc.topo, sc.demand)
    got_n = np.asarray(plan["n_pairs"])
    np.testing.assert_array_equal(got_n, np.asarray(want.matrix).sum(axis=1))


# ---------------------------------------------------------------------------
# Shared-port semantics
# ---------------------------------------------------------------------------


def test_lease_is_paid_once_attachments_per_pair():
    """Two pairs on one port: hourly CCI cost is L + 2V + c*(d1+d2), not
    2L + ... — the economics the per-link planner cannot express."""
    port = _one_port()
    topo = TopologySpec(
        ports=(port,),
        pairs=(_one_pair("a", (0,)), _one_pair("b", (0,))),
    )
    d = np.full((2, 200), 50.0)
    plan = plan_topology(topo, d, routing=topo.plan([0, 0]))
    cci = np.asarray(plan["cci_hourly"])[0]
    want = port.L_cci + 2 * port.V_cci + port.c_cci * 100.0
    np.testing.assert_allclose(cci, want, rtol=1e-12)
    vpn = np.asarray(plan["vpn_hourly"])[0]
    want_vpn = 2 * (0.105 + 0.1 * 50.0)
    np.testing.assert_allclose(vpn, want_vpn, rtol=1e-12)


def test_port_capacity_clips_aggregated_cci_demand_only():
    """The hard CCI ceiling (linksim F1) caps the port AGGREGATE; the VPN
    counterfactual rides the public internet and only sees the per-pair
    VLAN access clip."""
    cap = 120.0
    topo = TopologySpec(
        ports=(_one_port(capacity_gb_hr=cap),),
        pairs=(
            _one_pair("a", (0,), capacity_gb_hr=90.0),
            _one_pair("b", (0,), capacity_gb_hr=90.0),
        ),
    )
    d = np.full((2, 300), 1000.0)
    routing = topo.plan([0, 0])
    plan = plan_topology(topo, d, routing=routing)
    np.testing.assert_array_equal(np.asarray(plan["pair_demand"]), 90.0)
    np.testing.assert_array_equal(np.asarray(plan["port_demand"])[0], cap)
    # Reference clips identically -> identical decisions.
    ref = plan_topology_reference(topo, d, routing)
    np.testing.assert_array_equal(np.asarray(plan["x"]), ref["x"])


def test_unused_port_costs_nothing_and_stays_off():
    topo = TopologySpec(
        ports=(_one_port("used"), _one_port("idle", facility="fac01")),
        pairs=(_one_pair("a", (0, 1)),),
    )
    d = np.full((1, 400), 200.0)
    plan = plan_topology(topo, d, routing=topo.plan([0]))
    assert float(np.asarray(plan["toggle_cost"])[1]) == 0.0
    assert np.asarray(plan["x"])[1].sum() == 0
    assert float(np.asarray(plan["n_pairs"])[1]) == 0.0


def test_sharing_beats_dedicated_per_link_planning():
    """Two CCI-friendly pairs on one shared port must cost strictly less
    than the same routing priced per-link (each pair paying full L_cci)."""
    topo = TopologySpec(
        ports=(_one_port(),),
        pairs=(_one_pair("a", (0,)), _one_pair("b", (0,))),
    )
    rng = np.random.default_rng(0)
    d = rng.uniform(150.0, 250.0, size=(2, 1000))  # far above breakeven
    routing = topo.plan([0, 0])
    plan = plan_topology(topo, d, routing=routing)
    shared = float(np.sum(np.asarray(plan["toggle_cost"])))
    ded = plan_fleet(dedicated_fleet(topo, routing), d)
    dedicated = float(np.sum(np.asarray(ded["toggle_cost"])))
    assert shared < dedicated
    # The gap is at least half the duplicated lease (both links toggle ON
    # most of the horizon, so ~one extra L_cci is paid almost throughout).
    assert dedicated - shared > 0.5 * topo.ports[0].L_cci * d.shape[1] * 0.5


# ---------------------------------------------------------------------------
# Routing optimization
# ---------------------------------------------------------------------------


def test_optimize_routing_respects_candidates():
    sc = build_topology_scenario(16, n_facilities=4, horizon=600, seed=9)
    r = optimize_routing(sc.topo, sc.demand)
    cand = sc.topo.candidate_matrix()
    for i, m in enumerate(r.primary):
        assert cand[i, m]


def test_optimize_routing_packs_shared_leases():
    """Pairs with a common candidate port get packed together: the number
    of opened ports must be well under one-per-pair."""
    sc = build_topology_scenario(24, n_facilities=3, horizon=600, seed=2)
    r = optimize_routing(sc.topo, sc.demand)
    assert len(r.ports_used()) < sc.n_pairs / 2


def test_optimize_routing_respects_capacity_headroom():
    small, big = 100.0, 1e6
    topo = TopologySpec(
        ports=(
            _one_port("small", capacity_gb_hr=small),
            _one_port("big", L_cci=20.0, capacity_gb_hr=big),
        ),
        pairs=tuple(_one_pair(f"p{i}", (0, 1)) for i in range(4)),
    )
    d = np.full((4, 100), 60.0)  # any 2 pairs together exceed the small port
    prim = optimize_routing(topo, d, headroom=0.9).primary
    # First pair fits the cheap small port; the rest must spill to the big one.
    assert (prim == 0).sum() == 1 and (prim == 1).sum() == 3


def test_optimize_routing_falls_back_when_everything_is_full():
    topo = TopologySpec(
        ports=(_one_port("only", capacity_gb_hr=10.0),),
        pairs=(_one_pair("a", (0,)), _one_pair("b", (0,))),
    )
    d = np.full((2, 50), 500.0)
    r = optimize_routing(topo, d)  # no feasible port: least-loaded fallback
    np.testing.assert_array_equal(r.primary, [0, 0])


# ---------------------------------------------------------------------------
# Scenario builder
# ---------------------------------------------------------------------------


def test_topology_scenario_shapes_and_calibration():
    sc = build_topology_scenario(
        10, n_facilities=3, ports_per_facility=2, horizon=HORIZON, seed=4
    )
    assert isinstance(sc, TopologyScenario)
    assert sc.demand.shape == (10, HORIZON)
    assert (sc.demand >= 0).all()
    assert sc.n_ports == 6
    assert set(p.cloud for p in sc.topo.ports) == {"aws", "azure"}
    for po in sc.topo.ports:
        assert po.capacity_gb_hr in (port_capacity_gb_hr(), port_capacity_gb_hr(100.0))
    for pr in sc.topo.pairs:
        other = pr.dst if pr.src == "gcp" else pr.src
        # candidates all live on the pair's cloud, within `reach` facilities
        facs = {sc.topo.ports[c].facility for c in pr.candidates}
        assert len(facs) <= 2
        assert all(sc.topo.ports[c].cloud == other for c in pr.candidates)
        assert pr.capacity_gb_hr in [vlan_access_gb_hr(v) for v in (1, 2, 5, 10)]


def test_linksim_calibrated_port_capacity():
    from repro.traffic import linksim

    assert port_capacity_gb_hr() == pytest.approx(10.0 * 0.95 * 450.0)
    assert linksim.cci_port_capacity_gbps(100.0) == pytest.approx(95.0)
    assert vlan_access_gb_hr(2) == pytest.approx(2 * 1.7 * 450.0)


# ---------------------------------------------------------------------------
# Report layer
# ---------------------------------------------------------------------------


def test_topology_report_savings_and_oracle_bound():
    sc = build_topology_scenario(12, n_facilities=3, horizon=HORIZON, seed=11)
    routing = optimize_routing(sc.topo, sc.demand)
    plan = plan_topology(sc.topo, sc.demand, routing=routing)
    rep = build_topology_report(sc, plan, routing, include_oracle=True)
    assert len(rep.ports) == sc.n_ports
    assert rep.ports_used == len(routing.ports_used())
    t = rep.totals
    assert t["togglecci"] == pytest.approx(sum(p.toggle_cost for p in rep.ports))
    # Per-port OPT (same routing) lower-bounds ToggleCCI and best-static.
    for p in rep.ports:
        assert p.oracle_cost is not None
        assert p.oracle_cost <= p.toggle_cost * (1 + 1e-9)
        assert p.oracle_cost <= p.best_static * (1 + 1e-9)
    assert "oracle_gap" in t and t["oracle_gap"] >= 1.0 - 1e-9
    # Shared leases must not cost MORE than the per-link view of the same
    # routing, and the multi-pair scenario should show real savings.
    assert "lease_sharing_savings" in t
    assert t["lease_sharing_savings"] > 0.0
    text = rep.render_text()
    assert "topology total" in text and "shared-lease saving" in text
    assert rep.ports[0].name in text


def test_topology_oracle_matches_manual_series():
    topo = TopologySpec(
        ports=(_one_port(),),
        pairs=(_one_pair("a", (0,)),),
    )
    d = np.full((1, 300), 150.0)
    oc = topology_oracle(topo, d, [0])
    assert oc.shape == (1,)
    plan = plan_topology(topo, d, routing=topo.plan([0]))
    assert oc[0] <= float(np.asarray(plan["toggle_cost"])[0]) * (1 + 1e-9)


def test_window_sums_public_api():
    r = np.asarray(window_sums(np.ones(10), 3))
    np.testing.assert_allclose(r, [0, 1, 2, 3, 3, 3, 3, 3, 3, 3])
